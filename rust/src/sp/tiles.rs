//! Tile-level attention operations + the per-rank accumulator.
//!
//! Every SP algorithm reduces distributed attention to three tile ops on
//! `[B, chunk, g, D]` blocks — exactly the contract of the L1 Pallas
//! kernel (Algorithm 2: multiple Q/KV tensors, carried (O', l, m) state,
//! finalize-on-last):
//!
//! * [`attn_partial`] — one KV tile merged into a q-tile's carried state;
//! * [`merge_states`] — combine two states (Appendix C Eq. 3);
//! * [`finalize`]     — O = O' / l.
//!
//! In numeric mode these dispatch to the AOT artifacts
//! `attn_{partial,merge,finalize}_{cfg}_h{g}`; in timing mode they only
//! advance the virtual clock by the roofline cost model. [`AttnAccum`]
//! wraps a rank's q tiles + states and is the workspace all algorithms
//! share.

use crate::cluster::exec::{ExecMode, RankCtx};
use crate::comm::Buf;

use super::AttnState;

fn dims4(b: &Buf) -> (usize, usize, usize, usize) {
    let s = b.shape();
    assert_eq!(s.len(), 4, "expected [B, l, g, D], got {s:?}");
    (s[0], s[1], s[2], s[3])
}

/// Merge one KV tile into the carried state of a q tile.
///
/// `q: [B, lq, g, D]`, `k`/`v`: `[B, lk, g, D]`. Numeric mode requires
/// `lq == lk == cfg.chunk` and `g ∈ cfg.head_groups` (the lowered tile
/// set); timing mode takes any shape.
pub fn attn_partial(ctx: &mut RankCtx, q: &Buf, k: &Buf, v: &Buf, st: AttnState) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    let (_, lk, _, _) = dims4(k);
    ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    match &ctx.mode {
        ExecMode::Timing => st,
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        q.tensor().clone(),
                        k.tensor().clone(),
                        v.tensor().clone(),
                        st.o.into_tensor(),
                        st.l.into_tensor(),
                        st.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn_partial tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Span variant (§Perf optimization L3-2): absorb `span` chunk tiles of
/// KV in ONE fused artifact call (`attn_partial_*_s{span}`) — the
/// Algorithm-2 fusion. `k`/`v`: `[B, span·chunk, g, D]`.
pub fn attn_partial_span(
    ctx: &mut RankCtx,
    q: &Buf,
    k: &Buf,
    v: &Buf,
    st: AttnState,
    span: usize,
) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    let (_, lk, _, _) = dims4(k);
    ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    match &ctx.mode {
        ExecMode::Timing => st,
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}_s{}", cfg.name, g, span);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        q.tensor().clone(),
                        k.tensor().clone(),
                        v.tensor().clone(),
                        st.o.into_tensor(),
                        st.l.into_tensor(),
                        st.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn span tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Is the `s{span}` artifact available for head group `g`? (Timing mode:
/// always — the modelled GPU kernel fuses arbitrarily, like Algorithm 2.)
fn span_available(ctx: &RankCtx, g: usize, span: usize) -> bool {
    match &ctx.mode {
        ExecMode::Timing => true,
        ExecMode::Numeric { rt, cfg } => rt
            .manifest()
            .artifacts
            .contains_key(&format!("attn_partial_{}_h{}_s{}", cfg.name, g, span)),
    }
}

/// Carry-chain variant (§Perf optimization L3-1): merge a *sequence* of
/// KV tiles into one q tile's state with a single runtime roundtrip —
/// the (O', l, m) state stays on the PJRT service thread as XLA literals
/// between tiles. Numerically identical to folding [`attn_partial`].
pub fn attn_partial_chain(
    ctx: &mut RankCtx,
    q: &Buf,
    kvs: &[(Buf, Buf)],
    st: AttnState,
) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    for (k, _) in kvs {
        let (_, lk, _, _) = dims4(k);
        ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    }
    match &ctx.mode {
        ExecMode::Timing => st,
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}", cfg.name, g);
            let kv_tensors: Vec<(crate::tensor::Tensor, crate::tensor::Tensor)> = kvs
                .iter()
                .map(|(k, v)| (k.tensor().clone(), v.tensor().clone()))
                .collect();
            let out = rt
                .call_attn_chain(
                    &name,
                    q.tensor(),
                    kv_tensors,
                    (st.o.into_tensor(), st.l.into_tensor(), st.m.into_tensor()),
                )
                .unwrap_or_else(|e| panic!("attn chain failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Combine two carried states over the same q tile (Appendix C Eq. 3).
pub fn merge_states(ctx: &mut RankCtx, a: AttnState, b2: AttnState) -> AttnState {
    let (b, lq, g, d) = dims4(&a.o);
    // merge is memory-bound: touches ~4 state tensors
    let bytes = (2 * (b * lq * g * d) + 4 * (b * g * lq)) as f64 * 4.0;
    let t = ctx.cluster().gpu.tile_time(0.0, bytes);
    ctx.compute(t);
    match &ctx.mode {
        ExecMode::Timing => a,
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_merge_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        a.o.into_tensor(),
                        a.l.into_tensor(),
                        a.m.into_tensor(),
                        b2.o.into_tensor(),
                        b2.l.into_tensor(),
                        b2.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn_merge tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Normalize a carried state: O = O' / l.
pub fn finalize(ctx: &mut RankCtx, st: AttnState) -> Buf {
    let (b, lq, g, d) = dims4(&st.o);
    let bytes = (2 * (b * lq * g * d) + b * g * lq) as f64 * 4.0;
    let t = ctx.cluster().gpu.tile_time(0.0, bytes);
    ctx.compute(t);
    match &ctx.mode {
        ExecMode::Timing => st.o,
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_finalize_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(&name, vec![st.o.into_tensor(), st.l.into_tensor()])
                .unwrap_or_else(|e| panic!("attn_finalize tile failed: {e}"));
            Buf::Real(out.into_iter().next().unwrap())
        }
    }
}

/// Per-rank attention workspace: a list of q tiles (each `[B, chunk, g,
/// D]`) with their carried states. KV tiles are absorbed as they arrive
/// (from the ring, the torus stages, or local chunking); `finish`
/// finalizes and reassembles the output in q order.
pub struct AttnAccum {
    pub chunk: usize,
    q_tiles: Vec<Buf>,
    states: Vec<AttnState>,
}

impl AttnAccum {
    /// Split `q` (`[B, Ls, g, D]`, `chunk | Ls`) into tiles with zeroed
    /// states.
    pub fn new(ctx: &RankCtx, q: &Buf, chunk: usize) -> Self {
        let (b, ls, g, d) = dims4(q);
        assert_eq!(ls % chunk, 0, "q len {ls} not a multiple of chunk {chunk}");
        let numeric = ctx.mode.is_numeric();
        let parts = q.split(1, ls / chunk);
        let states = parts
            .iter()
            .map(|_| AttnState::zero(b, chunk, g, d, numeric))
            .collect();
        Self { chunk, q_tiles: parts, states }
    }

    /// Append more q tiles (Torus: pulled Q chunks join the workspace).
    pub fn push_q(&mut self, ctx: &RankCtx, q: &Buf) {
        let (b, ls, g, d) = dims4(q);
        assert_eq!(ls % self.chunk, 0);
        let numeric = ctx.mode.is_numeric();
        for t in q.split(1, ls / self.chunk) {
            self.q_tiles.push(t);
            self.states.push(AttnState::zero(b, self.chunk, g, d, numeric));
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.q_tiles.len()
    }

    /// Absorb a KV block (`[B, Lk, g, D]`, `chunk | Lk`) into the states
    /// of q tiles `idx` (all tiles if `None`). Multi-tile blocks go
    /// through the carry-chain fast path (one runtime roundtrip per q
    /// tile instead of one per KV tile).
    pub fn absorb(&mut self, ctx: &mut RankCtx, k: &Buf, v: &Buf, idx: Option<&[usize]>) {
        let (_, lk, g, _) = dims4(k);
        assert_eq!(lk % self.chunk, 0, "kv len {lk} not a multiple of chunk");
        let nt = lk / self.chunk;
        let all: Vec<usize> = (0..self.q_tiles.len()).collect();
        let targets = idx.unwrap_or(&all);
        // Greedy span decomposition (§Perf L3-2): absorb the block in as
        // few fused calls as possible — largest power-of-two span
        // artifacts first, chunk-sized calls for leftovers.
        let mut plan: Vec<(usize, usize)> = Vec::new(); // (tile offset, span)
        let mut off = 0;
        while off < nt {
            let mut span = 1usize;
            while span * 2 <= nt - off && span_available(ctx, g, span * 2) {
                span *= 2;
            }
            plan.push((off, span));
            off += span;
        }
        for &i in targets {
            let mut st = std::mem::replace(
                &mut self.states[i],
                AttnState::zero(1, 1, 1, 1, false),
            );
            for &(o, span) in &plan {
                let kb = k.slice(1, o * self.chunk, (o + span) * self.chunk);
                let vb = v.slice(1, o * self.chunk, (o + span) * self.chunk);
                if span == 1 {
                    st = attn_partial(ctx, &self.q_tiles[i], &kb, &vb, st);
                } else {
                    st = attn_partial_span(ctx, &self.q_tiles[i], &kb, &vb, st, span);
                }
            }
            self.states[i] = st;
        }
    }

    /// Finalize tiles `idx` (or all) and return their outputs in order.
    pub fn finish_tiles(&mut self, ctx: &mut RankCtx, idx: &[usize]) -> Vec<Buf> {
        idx.iter()
            .map(|&i| {
                let st = std::mem::replace(
                    &mut self.states[i],
                    AttnState::zero(1, 1, 1, 1, false),
                );
                finalize(ctx, st)
            })
            .collect()
    }

    /// Finalize everything and concatenate along the sequence axis.
    pub fn finish(mut self, ctx: &mut RankCtx) -> Buf {
        let n = self.q_tiles.len();
        let idx: Vec<usize> = (0..n).collect();
        let outs = self.finish_tiles(ctx, &idx);
        Buf::concat(&outs, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::config::ClusterSpec;

    // Numeric-mode tile tests live in rust/tests/ (need artifacts);
    // here: timing-mode structure + cost accounting.

    #[test]
    fn accum_splits_and_reassembles() {
        let c = ClusterSpec::new(1, 1);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 64, 2, 16]);
            let k = Buf::Shape(vec![1, 64, 2, 16]);
            let v = k.clone();
            let mut acc = AttnAccum::new(ctx, &q, 16);
            assert_eq!(acc.num_tiles(), 4);
            acc.absorb(ctx, &k, &v, None);
            let out = acc.finish(ctx);
            assert_eq!(out.shape(), &[1, 64, 2, 16]);
            ctx.clock.now
        });
        assert!(run.outputs[0] > 0.0, "tile ops must cost time");
    }

    #[test]
    fn absorb_subset_only_charges_subset() {
        let c = ClusterSpec::new(1, 1);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 64, 2, 16]);
            let kv = Buf::Shape(vec![1, 16, 2, 16]);
            let mut acc = AttnAccum::new(ctx, &q, 16);
            let t0 = ctx.clock.now;
            acc.absorb(ctx, &kv, &kv, Some(&[0]));
            let one = ctx.clock.now - t0;
            let t1 = ctx.clock.now;
            acc.absorb(ctx, &kv, &kv, None);
            let all = ctx.clock.now - t1;
            (one, all)
        });
        let (one, all) = run.outputs[0];
        assert!(all > 3.0 * one, "4 tiles should cost ~4x one tile");
    }

    #[test]
    fn push_q_extends_workspace() {
        let c = ClusterSpec::new(1, 1);
        run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 32, 1, 8]);
            let mut acc = AttnAccum::new(ctx, &q, 32);
            assert_eq!(acc.num_tiles(), 1);
            acc.push_q(ctx, &Buf::Shape(vec![1, 64, 1, 8]));
            assert_eq!(acc.num_tiles(), 3);
        });
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn accum_rejects_ragged_q() {
        let c = ClusterSpec::new(1, 1);
        run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 30, 1, 8]);
            AttnAccum::new(ctx, &q, 16);
        });
    }
}
