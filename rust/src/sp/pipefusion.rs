//! PipeFusion: patch-level *displaced* pipeline parallelism over the
//! one-sided comm layer — the third dimension of the `cfg × pp × sp`
//! plan space.
//!
//! A `pp_degree`-stage plan partitions the DiT layers across the
//! [`crate::cluster::plan::ParallelGroup`]'s contiguous, machine-aligned
//! pipeline stages; the latent sequence is split into `patches` patches
//! that stream stage-to-stage through one-sided `put`s, so each stage is
//! computing one patch while its successors work on earlier patches and
//! its predecessors on later ones. Each stage is its own carved
//! [`crate::cluster::Mesh2D`], so any [`SpAlgo`] runs unchanged *inside*
//! a stage.
//!
//! ## Stale-activation (displaced) semantics
//!
//! Attention needs KV for the *whole* sequence, but a stage only has the
//! fresh activations of the patches that already arrived this diffusion
//! step. PipeFusion's observation is that diffusion inputs drift slowly
//! between consecutive steps (temporal redundancy), so each stage keeps
//! the **previous step's layer input as a stale KV cache** and serves
//! off-patch KV from it:
//!
//! * when patch `i` arrives, its cache slot is overwritten with the
//!   fresh activation *before* computing, so a patch always attends to
//!   its own fresh KV;
//! * patches `< i` of the current step are fresh too (their slots were
//!   overwritten earlier this step);
//! * patches `> i` are served one-step-stale.
//!
//! The per-patch inter-stage transfer is `B·(L/M)·H·D` activations —
//! independent of the SP degree — so pipelining slashes the
//! inter-machine volume whenever the sequence-parallel all-to-all would
//! otherwise cross machines ([`crate::analysis::plan_step_cost`] models
//! exactly this trade).
//!
//! ## Warm-up guarantee
//!
//! The **first step of a generation runs synchronously**: every stage
//! waits for all patches of its input, runs the plan's [`SpAlgo`] over
//! the full sequence on its stage mesh, and only then streams the result
//! onward. No stale KV is ever read, so the warm-up step equals the
//! plain-softmax oracle exactly (within the repo-wide 1e-4 f32 tolerance
//! of the tiled schedules — the same "exact, never approximate" bar the
//! SP algorithms meet, proven in `rust/tests/sp_property.rs`). Staleness
//! can therefore only ever appear *after* a fully-correct step, which is
//! what bounds the steady-state error: stale KV differs from fresh KV by
//! at most one step of input drift.

use anyhow::Result;

use crate::cluster::exec::{run_in_world, ExecMode, RankCtx};
use crate::cluster::plan::{BranchRole, ParallelGroup, ParallelPlan};
use crate::cluster::Mesh2D;
use crate::comm::{Buf, CommStats, CommWorld};
use crate::config::AttnShape;
use crate::tensor::Tensor;

use super::hybrid::guidance_combine;
use super::tiles::{host, AttnAccum};
use super::{SpAlgo, SpParams};

/// Knobs of the displaced patch pipeline shared by the numeric and
/// timing paths.
#[derive(Debug, Clone, Copy)]
pub struct PipeParams {
    /// Full per-branch attention shape `[B, L, H, D]`.
    pub shape: AttnShape,
    /// Tile granularity; must divide the per-rank patch shard
    /// `L / patches / sp_ranks`.
    pub chunk: usize,
    /// Number of patches the sequence streams through the pipeline as
    /// (PipeFusion's `M`).
    pub patches: usize,
}

impl PipeParams {
    /// Tokens per patch.
    pub fn patch_len(&self) -> usize {
        self.shape.l / self.patches
    }
}

/// Per-rank result of one branch step.
struct StageOut {
    /// The full fresh layer input this stage assembled this step — it
    /// becomes the stage's stale KV cache for the next step.
    input: Buf,
    /// Present on the last pipeline stage only: this rank's output
    /// shards — one per patch in streamed steps, a single contiguous SP
    /// shard in the synchronous warm-up step.
    out: Option<Vec<Buf>>,
}

/// One-sided allgather along the sequence axis within a stage mesh:
/// every rank exposes its shard under `slot` and pulls its peers',
/// reassembling the full sequence in rank order.
fn allgather_seq(
    ctx: &mut RankCtx,
    mesh: &Mesh2D,
    local: usize,
    own: Buf,
    slot: &str,
    flows: usize,
) -> Buf {
    let sp = mesh.total();
    if sp == 1 {
        return own;
    }
    ctx.expose(slot, own.clone());
    let mut parts: Vec<Option<Buf>> = vec![None; sp];
    parts[local] = Some(own);
    let mut pulls = Vec::new();
    for j in 0..sp {
        if j != local {
            pulls.push((j, ctx.get(mesh.base + j, slot, flows)));
        }
    }
    for (j, h) in pulls {
        parts[j] = Some(ctx.wait_get(h));
    }
    let bufs: Vec<Buf> = parts.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&bufs, 1)
}

/// One branch of one diffusion step on this rank's pipeline stage.
///
/// `x` is the step's full input latent (read by stage-0 ranks only —
/// later stages receive their input from their predecessor). `cache` is
/// the stage's stale KV cache as `patches` patch buffers; `None` selects
/// the synchronous warm-up schedule (no stale reads, the plan's `algo`
/// over the full sequence).
fn branch_step(
    ctx: &mut RankCtx,
    p: &PipeParams,
    group: &ParallelGroup,
    branch: &str,
    x: &Buf,
    cache: Option<Vec<Buf>>,
    algo: SpAlgo,
    flows: usize,
) -> StageOut {
    let stage = group.stage_of(ctx.rank);
    let mesh = &group.stages[stage];
    let sp = mesh.total();
    let local = ctx.rank - mesh.base;
    let last = stage + 1 == group.stages.len();
    let lp = p.patch_len();
    let lps = lp / sp;

    match cache {
        // ---- warm-up: synchronous, oracle-exact ------------------------
        None => {
            let x_full = if stage == 0 {
                x.clone()
            } else {
                let h = ctx.get(ctx.rank, &format!("pf.{branch}.s{stage}.sync.in"), flows);
                let own = ctx.wait_get(h);
                allgather_seq(
                    ctx,
                    mesh,
                    local,
                    own,
                    &format!("pf.{branch}.s{stage}.sync.ag"),
                    flows,
                )
            };
            // the plan's SP algorithm, unchanged, on the stage's carve
            let ls = p.shape.l / sp;
            let params = SpParams { shape: p.shape, chunk: p.chunk, mesh: mesh.clone() };
            let qs = x_full.slice(1, local * ls, (local + 1) * ls);
            let out = algo.run(ctx, &params, qs.clone(), qs.clone(), qs);
            let outs = if last {
                Some(vec![out])
            } else {
                let next = group.stages[stage + 1].base + local;
                ctx.put(next, &format!("pf.{branch}.s{}.sync.in", stage + 1), out, flows);
                None
            };
            StageOut { input: x_full, out: outs }
        }
        // ---- steady state: displaced patch pipeline --------------------
        Some(mut cache) => {
            debug_assert_eq!(cache.len(), p.patches, "cache must hold one buf per patch");
            let mut outs = Vec::new();
            let mut fresh = Vec::with_capacity(p.patches);
            for i in 0..p.patches {
                // fresh patch i: stage 0 slices the step input locally;
                // later stages receive their SP shard from the previous
                // stage and allgather the full patch for the KV update.
                let patch = if stage == 0 {
                    x.slice(1, i * lp, (i + 1) * lp)
                } else {
                    let h =
                        ctx.get(ctx.rank, &format!("pf.{branch}.s{stage}.p{i}.in"), flows);
                    let own = ctx.wait_get(h);
                    allgather_seq(
                        ctx,
                        mesh,
                        local,
                        own,
                        &format!("pf.{branch}.s{stage}.p{i}.ag"),
                        flows,
                    )
                };
                // displaced KV: own patch fresh before compute, earlier
                // patches fresh from this step, later ones one-step stale
                cache[i] = patch.clone();
                let q = patch.slice(1, local * lps, (local + 1) * lps);
                let mut accum = AttnAccum::new(ctx, &q, p.chunk);
                for kv in &cache {
                    accum.absorb(ctx, kv, kv, None);
                }
                let o = accum.finish(ctx);
                if last {
                    outs.push(o);
                } else {
                    let next = group.stages[stage + 1].base + local;
                    ctx.put(next, &format!("pf.{branch}.s{}.p{i}.in", stage + 1), o, flows);
                }
                fresh.push(patch);
            }
            StageOut {
                input: Buf::concat(&fresh, 1),
                out: if last { Some(outs) } else { None },
            }
        }
    }
}

/// Result of one guided diffusion step through the patch pipeline.
pub struct GuidedPipeStep {
    /// The CFG-combined output `[B, L, H, D]`.
    pub eps: Tensor,
    /// Per-stage fresh layer inputs of the conditional branch — next
    /// step's stale KV caches.
    pub cond_caches: Vec<Tensor>,
    /// Same for the unconditional branch.
    pub uncond_caches: Vec<Tensor>,
    /// Virtual-time makespan of the step.
    pub makespan: f64,
}

/// One branch's per-rank result: (assembled stage input, last-stage
/// output shards).
type BranchResult = (Tensor, Option<Vec<Tensor>>);
/// Per-rank results, tagged by branch ("c" / "u").
type BranchOut = (&'static str, BranchResult);

fn branch_out<'a>(per_rank: &'a [BranchOut], tag: &str) -> &'a BranchResult {
    per_rank
        .iter()
        .find(|(t, _)| *t == tag)
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("missing '{tag}' branch output"))
}

/// Run one guided diffusion step of the displaced patch pipeline under
/// `plan` with real tensors. `caches` carries each branch's per-stage
/// stale layer inputs from the previous step; `None` selects the
/// synchronous warm-up schedule (oracle-exact, see the module docs).
/// Each DiT "layer block" here is one self-attention layer per stage
/// (`x → attn(x, x, x)` stacked `pp_degree` times), the same toy network
/// [`guided_pipefusion_oracle`] evaluates exactly.
pub fn guided_pipefusion_step(
    plan: &ParallelPlan,
    p: &PipeParams,
    cond_x: &Tensor,
    uncond_x: &Tensor,
    scale: f32,
    caches: Option<(&[Tensor], &[Tensor])>,
    mode: &ExecMode,
) -> Result<GuidedPipeStep> {
    anyhow::ensure!(mode.is_numeric(), "pipefusion step needs a numeric ExecMode");
    plan.spec.validate_workload(&p.shape)?;
    plan.spec.validate_patches(&p.shape, p.patches)?;
    let sp = plan.spec.ranks_per_stage();
    let lps = p.patch_len() / sp;
    anyhow::ensure!(
        lps > 0 && lps % p.chunk == 0,
        "chunk {} must divide the per-rank patch shard {} (L={} patches={} sp={})",
        p.chunk,
        lps,
        p.shape.l,
        p.patches,
        sp
    );
    if let Some((c, u)) = caches {
        anyhow::ensure!(
            c.len() == plan.spec.pp_degree && u.len() == plan.spec.pp_degree,
            "caches must hold one layer input per pipeline stage"
        );
    }
    let warmup = caches.is_none();

    let world = CommWorld::new(plan.cluster.clone());
    world.set_cfg_fused(plan.cfg_fusible());
    let run = run_in_world(&world, mode, |ctx| {
        // ranks outside a subset plan's carve idle (other generation)
        let Some(group) = plan.try_group_of(ctx.rank) else {
            return Vec::new();
        };
        let flows = ctx.nic_flows(&group.ranks());
        let run_one = |ctx: &mut RankCtx,
                       branch: &'static str,
                       x: &Tensor,
                       cache_src: Option<&[Tensor]>|
         -> (Tensor, Option<Vec<Tensor>>) {
            let stage = group.stage_of(ctx.rank);
            let x_buf = Buf::Real(x.clone());
            let cache = cache_src.map(|c| Buf::Real(c[stage].clone()).split(1, p.patches));
            let so = branch_step(ctx, p, group, branch, &x_buf, cache, plan.algo, flows);
            (
                so.input.into_tensor(),
                so.out
                    .map(|v| v.into_iter().map(Buf::into_tensor).collect::<Vec<_>>()),
            )
        };
        match group.role {
            BranchRole::Conditional => {
                vec![("c", run_one(ctx, "c", cond_x, caches.map(|c| c.0)))]
            }
            BranchRole::Unconditional => {
                vec![("u", run_one(ctx, "u", uncond_x, caches.map(|c| c.1)))]
            }
            BranchRole::Both => {
                let c = run_one(ctx, "c", cond_x, caches.map(|c| c.0));
                // fresh window epoch so the second branch can never read
                // the first branch's exposed buffers
                ctx.next_epoch();
                let u = run_one(ctx, "u", uncond_x, caches.map(|c| c.1));
                vec![("c", c), ("u", u)]
            }
        }
    });

    // Assemble each branch from replica 0 of its role.
    let assemble = |role: BranchRole, tag: &str| -> Result<(Tensor, Vec<Tensor>)> {
        let group = plan.group_for(role, 0);
        let stage_caches: Vec<Tensor> = group
            .stages
            .iter()
            .map(|m| branch_out(&run.outputs[m.base], tag).0.clone())
            .collect();
        let last = group.stages.last().expect("pp_degree >= 1");
        let per_rank: Vec<&Vec<Tensor>> = last
            .ranks()
            .into_iter()
            .map(|r| {
                branch_out(&run.outputs[r], tag)
                    .1
                    .as_ref()
                    .unwrap_or_else(|| panic!("rank {r} missing last-stage output"))
            })
            .collect();
        let full = if warmup {
            // warm-up: one contiguous SP shard per rank, in rank order
            let shards: Vec<&Tensor> = per_rank.iter().map(|v| &v[0]).collect();
            Tensor::concat(&shards, 1)?
        } else {
            // streamed: per-patch shards, patch-major then rank-major
            let mut patch_outs: Vec<Tensor> = Vec::with_capacity(p.patches);
            for i in 0..p.patches {
                let shards: Vec<&Tensor> = per_rank.iter().map(|v| &v[i]).collect();
                patch_outs.push(Tensor::concat(&shards, 1)?);
            }
            let refs: Vec<&Tensor> = patch_outs.iter().collect();
            Tensor::concat(&refs, 1)?
        };
        Ok((full, stage_caches))
    };

    let (c_out, cond_caches) = assemble(BranchRole::Conditional, "c")?;
    let (u_out, uncond_caches) = assemble(BranchRole::Unconditional, "u")?;
    let eps = guidance_combine(&c_out, &u_out, scale)?;
    Ok(GuidedPipeStep { eps, cond_caches, uncond_caches, makespan: run.makespan() })
}

/// Exact single-device reference for one branch's stage stack: plain
/// softmax self-attention applied `pp` times.
pub fn stacked_attention_oracle(x: &Tensor, pp: usize) -> Tensor {
    let mut t = x.clone();
    for _ in 0..pp {
        t = host::attention_oracle(&t, &t, &t);
    }
    t
}

/// Drive `steps` diffusion steps of the displaced patch pipeline: step 0
/// is the synchronous warm-up, later steps stream patches against
/// one-step-stale KV. The latent update `x ← x + η·(eps − x)` models the
/// slowly-drifting inputs PipeFusion's temporal-redundancy argument
/// relies on; `cond_bias` is a fixed conditioning offset so the two
/// guidance branches differ. Returns the final latent and the summed
/// per-step makespan.
pub fn guided_pipefusion_generate(
    plan: &ParallelPlan,
    p: &PipeParams,
    steps: usize,
    eta: f32,
    x0: &Tensor,
    cond_bias: &Tensor,
    scale: f32,
    mode: &ExecMode,
) -> Result<(Tensor, f64)> {
    let mut x = x0.clone();
    let mut caches: Option<(Vec<Tensor>, Vec<Tensor>)> = None;
    let mut makespan = 0.0;
    for _ in 0..steps {
        let xc = x.add(cond_bias)?;
        let step = guided_pipefusion_step(
            plan,
            p,
            &xc,
            &x,
            scale,
            caches.as_ref().map(|(c, u)| (c.as_slice(), u.as_slice())),
            mode,
        )?;
        makespan += step.makespan;
        x = x.add(&step.eps.sub(&x)?.scale(eta))?;
        caches = Some((step.cond_caches, step.uncond_caches));
    }
    Ok((x, makespan))
}

/// Exact (staleness-free) reference for [`guided_pipefusion_generate`]:
/// the same diffusion loop with plain-softmax attention stacks.
pub fn guided_pipefusion_oracle(
    pp: usize,
    steps: usize,
    eta: f32,
    x0: &Tensor,
    cond_bias: &Tensor,
    scale: f32,
) -> Result<Tensor> {
    let mut x = x0.clone();
    for _ in 0..steps {
        let c = stacked_attention_oracle(&x.add(cond_bias)?, pp);
        let u = stacked_attention_oracle(&x, pp);
        let eps = guidance_combine(&c, &u, scale)?;
        x = x.add(&eps.sub(&x)?.scale(eta))?;
    }
    Ok(x)
}

/// Virtual-time makespan of one steady-state step of the patch pipeline
/// in timing mode (shape-only buffers at paper scale), with each stage
/// running ONE attention layer — a "pp-layer block". Callers model a
/// full network by dividing by `pp_degree` (per-layer equivalent) and
/// scaling by layer count; see `SimService::plan_layer_time`. `cfg_evals`
/// mirrors [`super::hybrid::hybrid_layer_makespan`]: a `cfg_degree == 1`
/// plan pays the guidance branches sequentially, a CFG-parallel plan
/// concurrently.
pub fn pipefusion_layer_makespan(
    plan: &ParallelPlan,
    shape: AttnShape,
    chunk: usize,
    patches: usize,
    cfg_evals: usize,
) -> f64 {
    pipefusion_layer_makespan_traced(plan, shape, chunk, patches, cfg_evals).0
}

/// [`pipefusion_layer_makespan`] plus the run's measured comm counters —
/// the serve engine accumulates these into the report's `comm` section.
pub fn pipefusion_layer_makespan_traced(
    plan: &ParallelPlan,
    shape: AttnShape,
    chunk: usize,
    patches: usize,
    cfg_evals: usize,
) -> (f64, CommStats) {
    let p = PipeParams { shape, chunk, patches };
    let lp = p.patch_len();
    let world = CommWorld::new(plan.cluster.clone());
    world.set_cfg_fused(plan.cfg_fusible());
    let run = run_in_world(&world, &ExecMode::Timing, |ctx| {
        // ranks outside a subset plan's carve idle (other generation)
        let Some(group) = plan.try_group_of(ctx.rank) else {
            return;
        };
        let flows = ctx.nic_flows(&group.ranks());
        let branches = match group.role {
            BranchRole::Both => cfg_evals,
            BranchRole::Conditional => 1,
            BranchRole::Unconditional => usize::from(cfg_evals >= 2),
        };
        for b in 0..branches {
            let x = Buf::Shape(vec![shape.b, shape.l, shape.h, shape.d]);
            let cache: Vec<Buf> =
                vec![Buf::Shape(vec![shape.b, lp, shape.h, shape.d]); patches];
            branch_step(ctx, &p, group, &format!("t{b}"), &x, Some(cache), plan.algo, flows);
            ctx.next_epoch();
        }
    });
    (run.makespan(), world.stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, ParallelSpec, SpDegrees};

    #[test]
    fn timing_pipeline_runs_and_costs_time() {
        // 2 machines x 2 GPUs, pp2 x sp2: one stage per machine.
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(1, 2, 1, SpDegrees::new(2, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        let shape = AttnShape::new(1, 4096, 8, 64);
        let t = pipefusion_layer_makespan(&plan, shape, 4096 / 4 / 2, 4, 1);
        assert!(t > 0.0);
        // a second guidance eval on a cfg1 plan costs more
        let t2 = pipefusion_layer_makespan(&plan, shape, 4096 / 4 / 2, 4, 2);
        assert!(t2 > t, "sequential branches {t2} vs one {t}");
    }

    #[test]
    fn warmup_step_matches_stacked_oracle() {
        // pp2 x sp2 on one 4-GPU machine, synchronous warm-up.
        let cluster = ClusterSpec::new(1, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(1, 2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        let shape = AttnShape::new(1, 32, 4, 8);
        let p = PipeParams { shape, chunk: 4, patches: 2 };
        let dims = [1, 32, 4, 8];
        let x = Tensor::random(&dims, 11);
        let cb = Tensor::random(&dims, 12).scale(0.5);
        let step = guided_pipefusion_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            3.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guidance_combine(
            &stacked_attention_oracle(&x.add(&cb).unwrap(), 2),
            &stacked_attention_oracle(&x, 2),
            3.0,
        )
        .unwrap();
        let diff = step.eps.max_abs_diff(&want);
        assert!(diff < 1e-4, "warm-up vs stacked oracle: {diff}");
        assert!(step.makespan > 0.0);
        // the warm-up caches are the stages' exact layer inputs
        assert_eq!(step.cond_caches.len(), 2);
        let c0 = step.cond_caches[0].max_abs_diff(&x.add(&cb).unwrap());
        assert!(c0 < 1e-6, "stage-0 cache is the step input: {c0}");
    }

    #[test]
    fn streamed_step_reads_stale_kv_but_stays_bounded() {
        // After a warm-up, a streamed step against *unchanged* inputs
        // must reproduce the oracle exactly (the "stale" cache equals
        // the fresh activations when the input did not move).
        let cluster = ClusterSpec::new(1, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(1, 2, 1, SpDegrees::new(1, 1)),
            SpAlgo::Ring,
        )
        .unwrap();
        let shape = AttnShape::new(1, 16, 2, 4);
        let p = PipeParams { shape, chunk: 4, patches: 2 };
        let dims = [1, 16, 2, 4];
        let x = Tensor::random(&dims, 77);
        let cb = Tensor::random(&dims, 78).scale(0.5);
        let warm = guided_pipefusion_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            2.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let streamed = guided_pipefusion_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            2.0,
            Some((&warm.cond_caches, &warm.uncond_caches)),
            &ExecMode::HostNumeric,
        )
        .unwrap();
        // both schedules are exact but reorder the softmax merge, so
        // each may sit up to 1e-4 from the true value
        let diff = streamed.eps.max_abs_diff(&warm.eps);
        assert!(diff < 2e-4, "fixed-point streamed step vs warm-up: {diff}");
    }

    #[test]
    fn step_rejects_bad_patch_divisibility() {
        let cluster = ClusterSpec::new(1, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(1, 2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        // L = 36 does not split into 4 patches over 2 stage ranks x chunk 4
        let shape = AttnShape::new(1, 36, 4, 8);
        let p = PipeParams { shape, chunk: 4, patches: 4 };
        let dims = [1, 36, 4, 8];
        let x = Tensor::random(&dims, 5);
        let err = guided_pipefusion_step(
            &plan,
            &p,
            &x,
            &x,
            1.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap_err();
        assert!(err.to_string().contains("patches"), "{err}");
    }
}
