//! Hybrid CFG×SP execution: run guided-diffusion attention under a
//! [`ParallelPlan`], with each guidance branch on its own group-scoped
//! sub-mesh, then merge branch outputs with the classifier-free-guidance
//! combine step.
//!
//! Classifier-free guidance evaluates the model twice per step — once
//! conditioned on the prompt, once unconditioned — and combines
//! `eps = eps_u + s · (eps_c − eps_u)`. A single-mesh plan
//! (`cfg_degree == 1`) runs the two branches back to back; a CFG-parallel
//! plan (`cfg_degree == 2`) runs them *concurrently* on disjoint halves
//! of the cluster, trading SP degree for branch parallelism (xDiT's
//! observation: near-linear extra scaling because the halves never
//! communicate until the cheap combine).

use anyhow::Result;

use crate::cluster::exec::{run_in_world, ExecMode};
use crate::cluster::plan::{BranchRole, ParallelPlan};
use crate::comm::{Buf, CommStats, CommWorld};
use crate::config::AttnShape;
use crate::tensor::{Tensor, TensorError};

use super::tiles;
use super::SpParams;

/// The CFG combine: `eps = uncond + scale · (cond − uncond)`.
pub fn guidance_combine(
    cond: &Tensor,
    uncond: &Tensor,
    scale: f32,
) -> Result<Tensor, TensorError> {
    uncond.add(&cond.sub(uncond)?.scale(scale))
}

/// Q/K/V for one guidance branch, full (unsharded) `[B, L, H, D]`.
pub type BranchQkv = (Tensor, Tensor, Tensor);

/// Single-device oracle for one guided attention layer: plain softmax
/// attention per branch + the guidance combine.
pub fn guided_attention_oracle(
    cond: &BranchQkv,
    uncond: &BranchQkv,
    scale: f32,
) -> Result<Tensor, TensorError> {
    let c = tiles::host::attention_oracle(&cond.0, &cond.1, &cond.2);
    let u = tiles::host::attention_oracle(&uncond.0, &uncond.1, &uncond.2);
    guidance_combine(&c, &u, scale)
}

/// Run one guided distributed attention layer under `plan` with real
/// tensors. Every rank executes only its group's branch on the group's
/// carved mesh; branch outputs are gathered from replica 0 of each branch
/// and merged with [`guidance_combine`]. Returns the combined output
/// `[B, L, H, D]` and the run's virtual-time makespan.
///
/// `mode` must carry real tensors (`HostNumeric`, or `Numeric` with
/// loaded artifacts); `shape` is the *per-branch* attention shape.
pub fn guided_attention_distributed(
    plan: &ParallelPlan,
    shape: AttnShape,
    chunk: usize,
    cond: &BranchQkv,
    uncond: &BranchQkv,
    scale: f32,
    mode: &ExecMode,
) -> Result<(Tensor, f64)> {
    anyhow::ensure!(mode.is_numeric(), "guided layer needs a numeric ExecMode");
    anyhow::ensure!(
        plan.spec.pp_degree == 1,
        "guided_attention_distributed runs non-pipelined plans; use \
         sp::pipefusion for pp_degree > 1"
    );
    plan.spec.validate_workload(&shape)?;
    let sp_ranks = plan.spec.ranks_per_group();
    let ls = shape.l / sp_ranks;
    let algo = plan.algo;

    let shard = |t: &Tensor, local: usize| -> Buf {
        Buf::Real(
            t.slice(1, local * ls, (local + 1) * ls)
                .expect("branch shard slice"),
        )
    };

    // One thread per cluster rank; each runs its group's schedule. The
    // returned pair is (conditional shard, unconditional shard) — a
    // single-branch group fills only its side. Ranks outside the plan's
    // carve (a subset plan of a pod running two carve generations) idle.
    // The world is fused when the plan qualifies, so the branch pair's
    // lockstep inter-machine transfers price the shared handshake.
    let world = CommWorld::new(plan.cluster.clone());
    world.set_cfg_fused(plan.cfg_fusible());
    let run = run_in_world(&world, mode, |ctx| {
        let Some(group) = plan.try_group_of(ctx.rank) else {
            return (None, None);
        };
        let local = group.local_rank(ctx.rank);
        let params = SpParams { shape, chunk, mesh: group.mesh().clone() };
        let run_branch = |ctx: &mut crate::cluster::exec::RankCtx, qkv: &BranchQkv| {
            let out = algo.run(
                ctx,
                &params,
                shard(&qkv.0, local),
                shard(&qkv.1, local),
                shard(&qkv.2, local),
            );
            out.into_tensor()
        };
        match group.role {
            BranchRole::Conditional => (Some(run_branch(ctx, cond)), None),
            BranchRole::Unconditional => (None, Some(run_branch(ctx, uncond))),
            BranchRole::Both => {
                let c = run_branch(ctx, cond);
                // fresh window epoch so the second branch can never read
                // the first branch's exposed buffers
                ctx.next_epoch();
                let u = run_branch(ctx, uncond);
                (Some(c), Some(u))
            }
        }
    });

    // Gather each branch from replica 0 of its group, in rank order.
    let gather = |role: BranchRole| -> Result<Tensor> {
        let group = plan.group_for(role, 0);
        let shards: Vec<&Tensor> = group
            .ranks()
            .into_iter()
            .map(|r| {
                let (c, u) = &run.outputs[r];
                let side = if matches!(role, BranchRole::Unconditional) { u } else { c };
                side.as_ref()
                    .unwrap_or_else(|| panic!("rank {r} missing {role:?} branch output"))
            })
            .collect();
        Ok(Tensor::concat(&shards, 1)?)
    };
    let c = gather(BranchRole::Conditional)?;
    let u = gather(BranchRole::Unconditional)?;
    let combined = guidance_combine(&c, &u, scale)?;
    Ok((combined, run.makespan()))
}

/// Virtual-time makespan of one attention layer under `plan` in timing
/// mode (shape-only buffers at paper scale): the executable hybrid cost
/// model `benches/fig_hybrid.rs` ranks plans with. `cfg_evals` is how
/// many guidance branches the workload needs (1 for distilled models, 2
/// for CFG): a `cfg_degree == 1` plan pays them sequentially on its
/// single mesh, a CFG-parallel plan pays them concurrently, and
/// unconditional groups idle when the workload has no second branch.
pub fn hybrid_layer_makespan(
    plan: &ParallelPlan,
    shape: AttnShape,
    chunk: usize,
    cfg_evals: usize,
) -> f64 {
    hybrid_layer_makespan_traced(plan, shape, chunk, cfg_evals).0
}

/// [`hybrid_layer_makespan`] plus the run's measured comm counters —
/// the serve engine accumulates these into the report's `comm` section.
pub fn hybrid_layer_makespan_traced(
    plan: &ParallelPlan,
    shape: AttnShape,
    chunk: usize,
    cfg_evals: usize,
) -> (f64, CommStats) {
    debug_assert_eq!(
        plan.spec.pp_degree, 1,
        "pipelined plans are timed by sp::pipefusion::pipefusion_layer_makespan"
    );
    let sp_ranks = plan.spec.ranks_per_group();
    let ls = shape.l / sp_ranks;
    let algo = plan.algo;
    let world = CommWorld::new(plan.cluster.clone());
    world.set_cfg_fused(plan.cfg_fusible());
    let run = run_in_world(&world, &ExecMode::Timing, |ctx| {
        // ranks outside a subset plan's carve idle (other generation)
        let Some(group) = plan.try_group_of(ctx.rank) else {
            return;
        };
        let params = SpParams { shape, chunk, mesh: group.mesh().clone() };
        let branches = match group.role {
            BranchRole::Both => cfg_evals,
            BranchRole::Conditional => 1,
            BranchRole::Unconditional => usize::from(cfg_evals >= 2),
        };
        for _ in 0..branches {
            let s = Buf::Shape(vec![shape.b, ls, shape.h, shape.d]);
            algo.run(ctx, &params, s.clone(), s.clone(), s);
            ctx.next_epoch();
        }
    });
    (run.makespan(), world.stats())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::plan::ParallelPlan;
    use crate::config::{ClusterSpec, ParallelSpec, SpDegrees};
    use crate::sp::SpAlgo;

    fn qkv(shape: &AttnShape, seed: u64) -> BranchQkv {
        let dims = [shape.b, shape.l, shape.h, shape.d];
        (
            Tensor::random(&dims, seed),
            Tensor::random(&dims, seed + 1),
            Tensor::random(&dims, seed + 2),
        )
    }

    #[test]
    fn guidance_combine_endpoints() {
        let c = Tensor::full(&[2, 2], 3.0);
        let u = Tensor::full(&[2, 2], 1.0);
        // scale 0 -> unconditional; scale 1 -> conditional
        assert_eq!(guidance_combine(&c, &u, 0.0).unwrap(), u);
        assert_eq!(guidance_combine(&c, &u, 1.0).unwrap(), c);
        // scale 2 extrapolates past the conditional branch
        assert_eq!(guidance_combine(&c, &u, 2.0).unwrap().data()[0], 5.0);
    }

    #[test]
    fn cfg_parallel_matches_oracle_host_numeric() {
        // 2x2 cluster, cfg_degree=2: each branch on a 2-rank carved mesh.
        let cluster = ClusterSpec::new(2, 2);
        let shape = AttnShape::new(1, 64, 4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        let cond = qkv(&shape, 100);
        let uncond = qkv(&shape, 200);
        let (got, makespan) = guided_attention_distributed(
            &plan,
            shape,
            32,
            &cond,
            &uncond,
            7.5,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guided_attention_oracle(&cond, &uncond, 7.5).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-4, "cfg-parallel vs oracle: {diff}");
        assert!(makespan > 0.0);
    }

    #[test]
    fn single_mesh_plan_matches_cfg_parallel() {
        // The same guided layer through a cfg_degree=1 plan (sequential
        // branches, SP over all 4 ranks) must agree with the oracle too.
        let cluster = ClusterSpec::new(2, 2);
        let shape = AttnShape::new(1, 64, 4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(4, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        let cond = qkv(&shape, 300);
        let uncond = qkv(&shape, 400);
        let (got, _) = guided_attention_distributed(
            &plan,
            shape,
            16,
            &cond,
            &uncond,
            3.0,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guided_attention_oracle(&cond, &uncond, 3.0).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn timing_mode_cfg_parallel_beats_sequential_branches() {
        // Same hardware, same workload: running the two branches
        // concurrently on halves must beat running them sequentially on
        // the full mesh when the full-mesh SP efficiency is sub-linear.
        let cluster = ClusterSpec::new(4, 8);
        let shape = AttnShape::new(1, 65536, 8, 64);
        let seq = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(8, 4)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        let par = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(8, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        let t_seq = hybrid_layer_makespan(&seq, shape, shape.l / 32, 2);
        let t_par = hybrid_layer_makespan(&par, shape, shape.l / 16, 2);
        assert!(
            t_par < t_seq,
            "cfg-parallel {t_par} must beat sequential branches {t_seq}"
        );
    }

    #[test]
    fn cfg_fusion_lowers_makespan_only_for_fusible_plans() {
        // A cfg2 plan with machine-spanning groups pays inter-machine
        // transfers in both branches; fusing the branch pair halves the
        // per-transfer alpha and rendezvous, so the measured makespan
        // must strictly drop. A knob-off run of the same plan must be
        // unchanged vs a fresh default world (off-path safety).
        let mut cluster = ClusterSpec::new(4, 8);
        let shape = AttnShape::new(1, 65536, 8, 64);
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
        let plan = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
        let plain = hybrid_layer_makespan(&plan, shape, shape.l / 16, 2);
        cluster.net.cfg_fuse = true;
        let fused_plan = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
        assert!(fused_plan.cfg_fusible());
        let fused = hybrid_layer_makespan(&fused_plan, shape, shape.l / 16, 2);
        assert!(fused < plain, "fused {fused} must beat unfused {plain}");
    }

    #[test]
    fn workload_divisibility_rejected_cleanly() {
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        // L=65 is not divisible by the plan's 2 SP ranks
        let bad = AttnShape::new(1, 65, 4, 8);
        let cond = qkv(&bad, 1);
        let uncond = qkv(&bad, 2);
        let err = guided_attention_distributed(
            &plan,
            bad,
            13,
            &cond,
            &uncond,
            1.0,
            &ExecMode::HostNumeric,
        )
        .unwrap_err();
        assert!(err.to_string().contains("not divisible"));
    }
}
