//! Torus Attention (§4.3): chunked, overlap-scheduled all-to-all.
//!
//! The key observation: in Ulysses' all-to-all, the chunk whose head index
//! equals the destination rank is **stationary** — it is already in place
//! before the exchange starts. Torus Attention therefore breaks each of
//! the four all-to-alls into per-peer chunks and pipelines them against
//! attention compute on whatever is already present:
//!
//! * **Pull Q** stages (×T): stage 1 computes the local `Q_{t,t}` against
//!   local `K_t,V_t`; stage k consumes the Q chunk pulled from rank
//!   `(t-k+1)%T` while later pulls are still in flight;
//! * **Pull KV** stages (×T−1): each pulled KV chunk is absorbed by all
//!   *pulled* Q tiles (the local Q's work is deferred);
//! * **Push O** stage: outputs owed to peers are pushed while the local
//!   `Q_t × pulled-KV` attention — saved for exactly this purpose —
//!   overlaps them.
//!
//! Each per-stage attention is itself a Ring Attention over the
//! intra-machine ring group (Algorithm 1's RINGATTN), and an intra-machine
//! Ulysses all-to-all (degree `P_u' = P_u / T`) runs before/after the
//! torus stages. The module is parameterized by [`CommStyle`]: `TwoSided`
//! is the ablation point "Torus over NCCL" (Appendix B); `OneSided` is
//! used by [`super::swiftfusion`] (Algorithm 1).

use crate::cluster::exec::RankCtx;
use crate::comm::Buf;

use super::tiles::AttnAccum;
use super::ulysses::all_to_all;
use super::SpParams;

/// Which communication library style the torus stages use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommStyle {
    /// NCCL analog: rendezvous sends, SM tax (ablation: "Torus (NCCL)").
    TwoSided,
    /// NVSHMEM analog: windows + put/get + explicit barriers (Algorithm 1).
    OneSided,
}

/// Subgroup geometry for the composed algorithm on rank `x`.
pub struct TorusGeometry {
    /// Torus group: one rank per machine slice of the Ulysses group.
    pub tgroup: Vec<usize>,
    /// This rank's torus index t.
    pub t: usize,
    /// Intra-machine Ulysses subgroup (degree P_u' = P_u / T).
    pub intra_u: Vec<usize>,
    /// Ring group (intra-machine for the SwiftFusion placement).
    pub rgroup: Vec<usize>,
}

impl TorusGeometry {
    /// Derive the geometry from the mesh: T = number of machines the
    /// Ulysses group spans (§4.3 assumes `N | P_u`). When `T ∤ P_u`
    /// (e.g. U4 over 3 machines), the paper's remedy is to apply Torus
    /// Attention only on a machine subset; we take the conservative
    /// variant: degrade to a single torus stage with the *whole* Ulysses
    /// group doing the (possibly inter-machine) all-to-all — i.e.
    /// topology-aware scheduling without chunk overlap for that config.
    pub fn new(p: &SpParams, rank: usize) -> Self {
        let ugroup = p.mesh.ulysses_group(rank);
        let mut machines: Vec<usize> = ugroup
            .iter()
            .map(|&r| p.mesh.cluster.machine_of(r))
            .collect();
        machines.sort_unstable();
        machines.dedup();
        let t_count = machines.len();
        if p.mesh.degrees.pu % t_count != 0 {
            // N ∤ P_u fallback: one stage, a2a across the full group.
            return Self {
                tgroup: vec![rank],
                t: 0,
                intra_u: ugroup,
                rgroup: p.mesh.ring_group(rank),
            };
        }
        let tgroup = p.mesh.torus_group(rank, t_count);
        let t = tgroup
            .iter()
            .position(|&r| r == rank)
            .expect("rank in its torus group");
        // intra-machine Ulysses subgroup: ugroup members on my machine
        let my_machine = p.mesh.cluster.machine_of(rank);
        let intra_u: Vec<usize> = ugroup
            .iter()
            .copied()
            .filter(|&r| p.mesh.cluster.machine_of(r) == my_machine)
            .collect();
        Self {
            tgroup,
            t,
            intra_u,
            rgroup: p.mesh.ring_group(rank),
        }
    }

    pub fn t_degree(&self) -> usize {
        self.tgroup.len()
    }
}

/// Inner per-stage attention: Ring Attention of some q tiles against one
/// KV chunk, sharded across the ring group.
fn stage_ring(
    ctx: &mut RankCtx,
    accum: &mut AttnAccum,
    geo: &TorusGeometry,
    k: &Buf,
    v: &Buf,
    q_idx: &[usize],
    style: CommStyle,
    stage_tag: &str,
    flows: usize,
) {
    if geo.rgroup.len() == 1 {
        accum.absorb(ctx, k, v, Some(q_idx));
        return;
    }
    match style {
        CommStyle::TwoSided => {
            // restrict the accumulator to the stage's q tiles by absorbing
            // ring blocks manually (ring_attention_group works on all
            // tiles, so run the ring loop here with the subset)
            ring_subset_two_sided(ctx, accum, geo, k, v, q_idx, flows);
        }
        CommStyle::OneSided => {
            // Algorithm 1 line 29: expose, Barrier(R), then pull freely.
            ctx.expose(&format!("{stage_tag}.k"), k.clone());
            ctx.expose(&format!("{stage_tag}.v"), v.clone());
            ctx.barrier(&geo.rgroup);
            ring_one_sided_subset(ctx, accum, geo, k, v, q_idx, stage_tag, flows);
        }
    }
}

fn ring_subset_two_sided(
    ctx: &mut RankCtx,
    accum: &mut AttnAccum,
    geo: &TorusGeometry,
    k: &Buf,
    v: &Buf,
    q_idx: &[usize],
    flows: usize,
) {
    let group = &geo.rgroup;
    let r = group.len();
    let me = group.iter().position(|&x| x == ctx.rank).unwrap();
    let next = group[(me + 1) % r];
    let prev = group[(me + r - 1) % r];
    let mut cur_k = k.clone();
    let mut cur_v = v.clone();
    for step in 0..r {
        let last = step == r - 1;
        let pending = if !last {
            let tk = format!("trs.k.{step}");
            let tv = format!("trs.v.{step}");
            let sk = ctx.isend(next, &tk, cur_k.clone());
            let sv = ctx.isend(next, &tv, cur_v.clone());
            let rk = ctx.irecv(prev, &tk, flows);
            let rv = ctx.irecv(prev, &tv, flows);
            Some((sk, sv, rk, rv))
        } else {
            None
        };
        accum.absorb(ctx, &cur_k, &cur_v, Some(q_idx));
        if let Some((sk, sv, rk, rv)) = pending {
            cur_k = ctx.wait_get(rk);
            cur_v = ctx.wait_get(rv);
            ctx.wait_send(sk);
            ctx.wait_send(sv);
        }
    }
}

fn ring_one_sided_subset(
    ctx: &mut RankCtx,
    accum: &mut AttnAccum,
    geo: &TorusGeometry,
    k: &Buf,
    v: &Buf,
    q_idx: &[usize],
    stage_tag: &str,
    flows: usize,
) {
    let group = &geo.rgroup;
    let r = group.len();
    let me = group.iter().position(|&x| x == ctx.rank).unwrap();
    let mut pending = Vec::new();
    for i in 1..r {
        let peer = group[(me + i) % r];
        let hk = ctx.get(peer, &format!("{stage_tag}.k"), flows);
        let hv = ctx.get(peer, &format!("{stage_tag}.v"), flows);
        pending.push((hk, hv));
    }
    accum.absorb(ctx, k, v, Some(q_idx));
    for (hk, hv) in pending {
        let kk = ctx.wait_get(hk);
        let vv = ctx.wait_get(hv);
        accum.absorb(ctx, &kk, &vv, Some(q_idx));
    }
}

/// The composed SwiftFusion/Torus dataflow (intra Ulysses → torus stages
/// with inner ring → inverse intra Ulysses), parameterized by comm style.
///
/// Input/output: this rank's sequence shard `[B, L/P, H, D]`.
pub fn composed_attention(
    ctx: &mut RankCtx,
    p: &SpParams,
    q: Buf,
    k: Buf,
    v: Buf,
    style: CommStyle,
) -> Buf {
    let geo = TorusGeometry::new(p, ctx.rank);
    let t_deg = geo.t_degree();
    let flows = ctx.nic_flows(&p.mesh.ranks());

    // ---- Phase 1: intra-machine Ulysses (cheap, blocking) -------------
    let q1 = all_to_all(ctx, &geo.intra_u, &q, 2, 1, "iu.q", flows);
    let k1 = all_to_all(ctx, &geo.intra_u, &k, 2, 1, "iu.k", flows);
    let v1 = all_to_all(ctx, &geo.intra_u, &v, 2, 1, "iu.v", flows);

    if t_deg == 1 {
        // No inter-machine dimension: plain ring attention + inverse a2a.
        let mut accum = AttnAccum::new(ctx, &q1, p.chunk);
        let all_idx: Vec<usize> = (0..accum.num_tiles()).collect();
        stage_ring(ctx, &mut accum, &geo, &k1, &v1, &all_idx, style, "t1ring", flows);
        let o = accum.finish(ctx);
        return all_to_all(ctx, &geo.intra_u, &o, 1, 2, "iu.o", flows);
    }

    // ---- Phase 2: torus stages over the inter-machine dimension -------
    // Split the head dim into T slices; slice τ belongs to torus rank τ.
    let q_sl = q1.split(2, t_deg);
    let k_sl = k1.split(2, t_deg);
    let v_sl = v1.split(2, t_deg);

    let out = match style {
        CommStyle::OneSided => torus_one_sided(ctx, p, &geo, q_sl, k_sl, v_sl, flows),
        CommStyle::TwoSided => torus_two_sided(ctx, p, &geo, q_sl, k_sl, v_sl, flows),
    };

    // ---- Phase 3: inverse intra-machine Ulysses ------------------------
    all_to_all(ctx, &geo.intra_u, &out, 1, 2, "iu.o", flows)
}

/// Torus stages with one-sided pulls/pushes (Algorithm 1 lines 15–36,
/// minus the global barriers which the caller owns).
fn torus_one_sided(
    ctx: &mut RankCtx,
    p: &SpParams,
    geo: &TorusGeometry,
    q_sl: Vec<Buf>,
    k_sl: Vec<Buf>,
    v_sl: Vec<Buf>,
    flows: usize,
) -> Buf {
    let t_deg = geo.t_degree();
    let t = geo.t;

    // Expose every head slice for peers to pull (the symmetric heap).
    for (i, qs) in q_sl.iter().enumerate() {
        ctx.expose(&format!("tq.{i}"), qs.clone());
    }
    for (i, ks) in k_sl.iter().enumerate() {
        ctx.expose(&format!("tk.{i}"), ks.clone());
    }
    for (i, vs) in v_sl.iter().enumerate() {
        ctx.expose(&format!("tv.{i}"), vs.clone());
    }
    // Peers must see the windows before pulling (caller's barrier_all for
    // SwiftFusion; a group barrier suffices when called standalone).
    ctx.barrier(&geo.tgroup);

    // Issue ALL pulls up front: Q chunks first (smaller, needed sooner),
    // then KV (Algorithm 1 lines 18–21).
    let mut q_pulls = Vec::new();
    for kk in 1..t_deg {
        let peer = geo.tgroup[(t + t_deg - kk) % t_deg];
        q_pulls.push(ctx.get(peer, &format!("tq.{t}"), flows));
    }
    let mut kv_pulls = Vec::new();
    for kk in 1..t_deg {
        let peer = geo.tgroup[(t + t_deg - kk) % t_deg];
        let hk = ctx.get(peer, &format!("tk.{t}"), flows);
        let hv = ctx.get(peer, &format!("tv.{t}"), flows);
        kv_pulls.push((hk, hv));
    }

    // Workspace: q tiles grouped by torus source; own slice first.
    let mut accum = AttnAccum::new(ctx, &q_sl[t], p.chunk);
    let tiles_per_chunk = accum.num_tiles();
    let own_idx: Vec<usize> = (0..tiles_per_chunk).collect();

    // ---- Pull Q stage 1: local Q_t × local K_t (ring over r) ----------
    stage_ring(
        ctx,
        &mut accum,
        geo,
        &k_sl[t],
        &v_sl[t],
        &own_idx,
        CommStyle::OneSided,
        "tsq.0",
        flows,
    );

    // ---- Pull Q stages 2..T: pulled Q × local K_t ----------------------
    let mut pulled_idx: Vec<usize> = Vec::new();
    for (kk, hq) in q_pulls.into_iter().enumerate() {
        let qc = ctx.wait_get(hq);
        let before = accum.num_tiles();
        accum.push_q(ctx, &qc);
        let idx: Vec<usize> = (before..accum.num_tiles()).collect();
        pulled_idx.extend(&idx);
        stage_ring(
            ctx,
            &mut accum,
            geo,
            &k_sl[t],
            &v_sl[t],
            &idx,
            CommStyle::OneSided,
            &format!("tsq.{}", kk + 1),
            flows,
        );
    }

    // ---- Pull KV stages 1..T-1: pulled KV × all *pulled* Q -------------
    let mut pulled_kv: Vec<(Buf, Buf)> = Vec::new();
    for (kk, (hk, hv)) in kv_pulls.into_iter().enumerate() {
        let kc = ctx.wait_get(hk);
        let vc = ctx.wait_get(hv);
        stage_ring(
            ctx,
            &mut accum,
            geo,
            &kc,
            &vc,
            &pulled_idx,
            CommStyle::OneSided,
            &format!("tskv.{kk}"),
            flows,
        );
        pulled_kv.push((kc, vc));
    }

    // ---- Push O: peers' outputs go out while local Q_t × pulled KV runs
    let pulled_out = accum.finish_tiles(ctx, &pulled_idx);
    // Reassemble per torus source (source order = pull order) and push.
    let mut push_events = Vec::new();
    for kk in 0..t_deg - 1 {
        let peer = geo.tgroup[(t + t_deg - 1 - kk) % t_deg];
        let chunk_tiles: Vec<Buf> =
            pulled_out[kk * tiles_per_chunk..(kk + 1) * tiles_per_chunk].to_vec();
        let o_chunk = Buf::concat(&chunk_tiles, 1);
        push_events.push(ctx.put(peer, &format!("to.{t}"), o_chunk, flows));
    }
    // Deferred local compute overlaps the pushes (the Push-O trick).
    for (kk, (kc, vc)) in pulled_kv.iter().enumerate() {
        stage_ring(
            ctx,
            &mut accum,
            geo,
            kc,
            vc,
            &own_idx,
            CommStyle::OneSided,
            &format!("tso.{kk}"),
            flows,
        );
    }
    let own_out = Buf::concat(&accum.finish_tiles(ctx, &own_idx), 1);

    // Collect O chunks pushed to us: peer τ pushed slot "to.{τ}".
    for ev in push_events {
        ctx.wait_event(ev);
    }
    let mut head_slices: Vec<Option<Buf>> = vec![None; t_deg];
    head_slices[t] = Some(own_out);
    for (i, slice) in head_slices.iter_mut().enumerate() {
        if i != t {
            let h = ctx.get(ctx.rank, &format!("to.{i}"), flows);
            *slice = Some(ctx.wait_get(h));
        }
    }
    let slices: Vec<Buf> = head_slices.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&slices, 2)
}

/// Torus stages with two-sided sends (the "Torus over NCCL" ablation).
/// Same schedule, but every chunk exchange is a rendezvous send/recv.
fn torus_two_sided(
    ctx: &mut RankCtx,
    p: &SpParams,
    geo: &TorusGeometry,
    q_sl: Vec<Buf>,
    k_sl: Vec<Buf>,
    v_sl: Vec<Buf>,
    flows: usize,
) -> Buf {
    let t_deg = geo.t_degree();
    let t = geo.t;

    // Issue all sends up front (Q first, then KV — same priority rule).
    let mut sends = Vec::new();
    for kk in 1..t_deg {
        let dest_t = (t + kk) % t_deg;
        let peer = geo.tgroup[dest_t];
        sends.push(ctx.isend(peer, &format!("twq.{t}"), q_sl[dest_t].clone()));
    }
    for kk in 1..t_deg {
        let dest_t = (t + kk) % t_deg;
        let peer = geo.tgroup[dest_t];
        sends.push(ctx.isend(peer, &format!("twk.{t}"), k_sl[dest_t].clone()));
        sends.push(ctx.isend(peer, &format!("twv.{t}"), v_sl[dest_t].clone()));
    }

    // Post ALL receives up front (Q first, then KV — the priority rule):
    // early-posted irecvs progress in the background like the one-sided
    // pulls, which is the whole point of the chunked schedule.
    let mut q_recvs = Vec::new();
    for kk in 1..t_deg {
        let src_t = (t + t_deg - kk) % t_deg;
        let peer = geo.tgroup[src_t];
        q_recvs.push(ctx.irecv(peer, &format!("twq.{src_t}"), flows));
    }
    let mut kv_recvs = Vec::new();
    for kk in 1..t_deg {
        let src_t = (t + t_deg - kk) % t_deg;
        let peer = geo.tgroup[src_t];
        let rk = ctx.irecv(peer, &format!("twk.{src_t}"), flows);
        let rv = ctx.irecv(peer, &format!("twv.{src_t}"), flows);
        kv_recvs.push((rk, rv));
    }

    let mut accum = AttnAccum::new(ctx, &q_sl[t], p.chunk);
    let tiles_per_chunk = accum.num_tiles();
    let own_idx: Vec<usize> = (0..tiles_per_chunk).collect();

    stage_ring(
        ctx,
        &mut accum,
        geo,
        &k_sl[t],
        &v_sl[t],
        &own_idx,
        CommStyle::TwoSided,
        "twsq.0",
        flows,
    );

    let mut pulled_idx: Vec<usize> = Vec::new();
    for rq in q_recvs {
        let qc = ctx.wait_get(rq);
        let before = accum.num_tiles();
        accum.push_q(ctx, &qc);
        let idx: Vec<usize> = (before..accum.num_tiles()).collect();
        pulled_idx.extend(&idx);
        stage_ring(
            ctx,
            &mut accum,
            geo,
            &k_sl[t],
            &v_sl[t],
            &idx,
            CommStyle::TwoSided,
            "twsq",
            flows,
        );
    }

    let mut pulled_kv = Vec::new();
    for (rk, rv) in kv_recvs {
        let kc = ctx.wait_get(rk);
        let vc = ctx.wait_get(rv);
        stage_ring(
            ctx,
            &mut accum,
            geo,
            &kc,
            &vc,
            &pulled_idx,
            CommStyle::TwoSided,
            "twskv",
            flows,
        );
        pulled_kv.push((kc, vc));
    }

    // Push O (two-sided): send pulled outputs home, overlap own compute.
    let pulled_out = accum.finish_tiles(ctx, &pulled_idx);
    let mut o_sends = Vec::new();
    for kk in 0..t_deg - 1 {
        let src_t = (t + t_deg - 1 - kk) % t_deg;
        let peer = geo.tgroup[src_t];
        let chunk_tiles: Vec<Buf> =
            pulled_out[kk * tiles_per_chunk..(kk + 1) * tiles_per_chunk].to_vec();
        o_sends.push(ctx.isend(peer, &format!("two.{t}"), Buf::concat(&chunk_tiles, 1)));
    }
    for (kk, (kc, vc)) in pulled_kv.iter().enumerate() {
        let _ = kk;
        stage_ring(ctx, &mut accum, geo, kc, vc, &own_idx, CommStyle::TwoSided, "twso", flows);
    }
    let own_out = Buf::concat(&accum.finish_tiles(ctx, &own_idx), 1);

    let mut head_slices: Vec<Option<Buf>> = vec![None; t_deg];
    head_slices[t] = Some(own_out);
    for i in 0..t_deg {
        if i != t {
            let peer = geo.tgroup[i];
            head_slices[i] = Some(ctx.wait_recv(peer, &format!("two.{i}"), flows));
        }
    }
    for h in o_sends {
        ctx.wait_send(h);
    }
    for h in sends {
        ctx.wait_send(h);
    }
    let slices: Vec<Buf> = head_slices.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&slices, 2)
}

/// SpAlgo::TorusNccl entry point.
pub fn torus_attention(
    ctx: &mut RankCtx,
    p: &SpParams,
    q: Buf,
    k: Buf,
    v: Buf,
    style: CommStyle,
) -> Buf {
    composed_attention(ctx, p, q, k, v, style)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::config::{AttnShape, ClusterSpec, SpDegrees};
    use crate::sp::SpAlgo;

    fn params(n: usize, m: usize, pu: usize) -> SpParams {
        let cluster = ClusterSpec::new(n, m);
        let total = n * m;
        SpParams {
            shape: AttnShape::new(1, 65536, 8, 64),
            chunk: 65536 / total,
            mesh: SpAlgo::SwiftFusion.mesh(&cluster, SpDegrees::new(pu, total / pu)),
        }
    }

    fn shard(p: &SpParams) -> Buf {
        Buf::Shape(vec![1, p.shard_len(), p.shape.h, p.shape.d])
    }

    #[test]
    fn geometry_paper_case() {
        // 4 machines x 8 GPUs, H=24 -> P_u=8, P_r=4: T=4, P_u'=2.
        let cluster = ClusterSpec::paper_testbed();
        let p = SpParams {
            shape: AttnShape::new(1, 1024, 24, 64),
            chunk: 32,
            mesh: SpAlgo::SwiftFusion.mesh(&cluster, SpDegrees::new(8, 4)),
        };
        let geo = TorusGeometry::new(&p, 0);
        assert_eq!(geo.t_degree(), 4);
        assert_eq!(geo.intra_u.len(), 2);
        assert_eq!(geo.rgroup.len(), 4);
        // torus group: one rank per machine
        let machines: std::collections::BTreeSet<_> = geo
            .tgroup
            .iter()
            .map(|&r| cluster.machine_of(r))
            .collect();
        assert_eq!(machines.len(), 4);
        // ring group intra-machine
        assert_eq!(p.mesh.inter_machine_fraction(&geo.rgroup), 0.0);
    }

    #[test]
    fn torus_shapes_roundtrip_both_styles() {
        for style in [CommStyle::OneSided, CommStyle::TwoSided] {
            let p = params(2, 2, 2);
            let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
                let out = composed_attention(ctx, &p, shard(&p), shard(&p), shard(&p), style);
                assert_eq!(out.shape(), shard(&p).shape(), "{style:?}");
                ctx.clock.now
            });
            assert!(run.makespan() > 0.0);
        }
    }

    #[test]
    fn one_sided_beats_two_sided() {
        // The Challenge-3 claim at the whole-algorithm level.
        let p = params(2, 2, 2);
        let two = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            composed_attention(ctx, &p, shard(&p), shard(&p), shard(&p), CommStyle::TwoSided);
        })
        .makespan();
        let one = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            composed_attention(ctx, &p, shard(&p), shard(&p), shard(&p), CommStyle::OneSided);
        })
        .makespan();
        assert!(one < two, "one-sided {one} vs two-sided {two}");
    }

    #[test]
    fn n_not_dividing_pu_falls_back() {
        // U4 over 3 machines x 8: T=3 does not divide P_u=4; geometry
        // must degrade to a single stage spanning the whole group.
        let cluster = ClusterSpec::new(3, 8);
        let p = SpParams {
            shape: AttnShape::new(1, 65536 - 65536 % 24, 24, 64),
            chunk: (65536 - 65536 % 24) / 24,
            mesh: SpAlgo::SwiftFusion.mesh(&cluster, SpDegrees::new(4, 6)),
        };
        let geo = TorusGeometry::new(&p, 0);
        assert_eq!(geo.t_degree(), 1);
        assert_eq!(geo.intra_u.len(), 4);
        // and the full algorithm still runs
        let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![1, p.shard_len(), 24, 64]);
            let out = composed_attention(ctx, &p, s.clone(), s.clone(), s, CommStyle::OneSided);
            assert_eq!(out.shape(), &[1, p.shard_len(), 24, 64]);
        });
        assert!(run.makespan() > 0.0);
    }

    #[test]
    fn degenerate_single_machine_runs() {
        // T=1: pure intra path must still work (paper: all methods
        // degrade to Ulysses on one machine).
        let p = params(1, 4, 4);
        let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            let out = composed_attention(
                ctx,
                &p,
                shard(&p),
                shard(&p),
                shard(&p),
                CommStyle::OneSided,
            );
            assert_eq!(out.shape(), shard(&p).shape());
        });
        assert!(run.makespan() > 0.0);
    }

    #[test]
    fn pu_prime_greater_than_one() {
        // 2 machines x 4 GPUs, P_u=4 (spans 2 machines, P_u'=2), P_r=2.
        let p = params(2, 4, 4);
        let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            let out = composed_attention(
                ctx,
                &p,
                shard(&p),
                shard(&p),
                shard(&p),
                CommStyle::OneSided,
            );
            assert_eq!(out.shape(), shard(&p).shape());
        });
        assert!(run.makespan() > 0.0);
    }
}
