//! SwiftFusion (Algorithm 1): the unified **one-sided** implementation of
//! Torus + Ulysses + Ring Attention.
//!
//! Synchronization structure is the paper's headline claim (§4.4): one
//! global barrier after the initial intra-machine ScatterPush, one global
//! barrier at the end after the final Push-O — and otherwise only
//! *intra-machine* barriers (the Ring group's per-stage `Barrier(R)`,
//! line 29). The sync-count integration test
//! (`rust/tests/sp_numerics.rs::alg1_sync_structure`) asserts exactly
//! this against the comm layer's barrier history.
//!
//! Phases (mirroring Algorithm 1's line numbers):
//! 1. **ScatterPush QKV** (line 15) — one-sided intra-machine Ulysses
//!    all-to-all: parts are `put` into peers' windows.
//! 2. **BarrierAll** (line 16) with quiet semantics (outstanding puts
//!    complete first, as `nvshmem_barrier_all_on_stream` guarantees).
//! 3. **Pull Q / Pull KV / Push O torus stages** (lines 18–35) via
//!    scheduling equivalent to [`super::torus`]'s one-sided path, with
//!    the one-sided RINGATTN (line 1–7) inside each stage.
//! 4. **ScatterPush O + BarrierAll** (lines 35–36) — inverse intra
//!    all-to-all, one-sided.

use crate::cluster::exec::RankCtx;
use crate::comm::{Buf, Event};

use super::torus::{CommStyle, TorusGeometry};
use super::tiles::AttnAccum;
use super::SpParams;

/// One-sided scatter of `buf` along `axis_split` to `group` (keeps own
/// part). Returns (own part, put events).
fn scatter_push(
    ctx: &mut RankCtx,
    group: &[usize],
    buf: &Buf,
    axis_split: usize,
    tag: &str,
    flows: usize,
) -> (Buf, Vec<Event>) {
    let u = group.len();
    let me = group.iter().position(|&x| x == ctx.rank).unwrap();
    if u == 1 {
        return (buf.clone(), Vec::new());
    }
    let parts = buf.split(axis_split, u);
    let mut events = Vec::new();
    for (j, part) in parts.iter().enumerate() {
        if j != me {
            events.push(ctx.put(group[j], &format!("sp.{tag}.{me}"), part.clone(), flows));
        }
    }
    (parts[me].clone(), events)
}

/// Assemble the gathered tensor from our window after a scatter_push
/// round: own part + peers' parts, concatenated along `axis_cat` in group
/// order.
fn gather_window(
    ctx: &mut RankCtx,
    group: &[usize],
    own: Buf,
    axis_cat: usize,
    tag: &str,
    flows: usize,
) -> Buf {
    let u = group.len();
    if u == 1 {
        return own;
    }
    let me = group.iter().position(|&x| x == ctx.rank).unwrap();
    let mut parts: Vec<Option<Buf>> = vec![None; u];
    parts[me] = Some(own);
    for j in 0..u {
        if j != me {
            let h = ctx.get(ctx.rank, &format!("sp.{tag}.{j}"), flows);
            parts[j] = Some(ctx.wait_get(h));
        }
    }
    let bufs: Vec<Buf> = parts.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&bufs, axis_cat)
}

/// Algorithm 1. Input/output: this rank's sequence shard `[B, L/P, H, D]`.
pub fn swiftfusion_attention(ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
    let geo = TorusGeometry::new(p, ctx.rank);
    let t_deg = geo.t_degree();
    let t = geo.t;
    let flows = ctx.nic_flows(&p.mesh.ranks());

    // ---- Phase 1: ScatterPush QKV within the intra-machine Ulysses
    // subgroup (line 15) + BarrierAll with quiet (line 16).
    let (q_own, eq) = scatter_push(ctx, &geo.intra_u, &q, 2, "q", flows);
    let (k_own, ek) = scatter_push(ctx, &geo.intra_u, &k, 2, "k", flows);
    let (v_own, ev) = scatter_push(ctx, &geo.intra_u, &v, 2, "v", flows);
    for e in eq.into_iter().chain(ek).chain(ev) {
        ctx.wait_event(e); // quiet
    }
    // mesh-wide barrier #1 ("global" = every rank of this mesh; on a
    // carved sub-mesh it must not synchronize with other partitions)
    ctx.barrier(&p.mesh.ranks());
    let q1 = gather_window(ctx, &geo.intra_u, q_own, 1, "q", flows);
    let k1 = gather_window(ctx, &geo.intra_u, k_own, 1, "k", flows);
    let v1 = gather_window(ctx, &geo.intra_u, v_own, 1, "v", flows);

    // ---- Phase 2: torus stages (lines 18-35) ---------------------------
    let o2 = if t_deg == 1 {
        // Single machine: degrade to (one-sided) Ring over the ring group.
        let mut accum = AttnAccum::new(ctx, &q1, p.chunk);
        one_sided_stage_ring(ctx, p, &geo, &mut accum, &k1, &v1, None, "sfu.r0", flows);
        accum.finish(ctx)
    } else {
        torus_stages_one_sided(ctx, p, &geo, q1, k1, v1, flows)
    };
    let _ = t;

    // ---- Phase 3: ScatterPush O (line 35) + BarrierAll (line 36) ------
    let (o_own, eo) = scatter_push(ctx, &geo.intra_u, &o2, 1, "o", flows);
    for e in eo {
        ctx.wait_event(e);
    }
    ctx.barrier(&p.mesh.ranks()); // mesh-wide barrier #2
    gather_window(ctx, &geo.intra_u, o_own, 2, "o", flows)
}

/// The one-sided RINGATTN (Algorithm 1 lines 1-7) restricted to q tiles
/// `idx` (None = all): expose the KV chunk, Barrier(R) (line 29's
/// intra-machine sync), pull peers' chunks directly by rank.
fn one_sided_stage_ring(
    ctx: &mut RankCtx,
    _p: &SpParams,
    geo: &TorusGeometry,
    accum: &mut AttnAccum,
    k: &Buf,
    v: &Buf,
    idx: Option<&[usize]>,
    stage_tag: &str,
    flows: usize,
) {
    let all: Vec<usize> = (0..accum.num_tiles()).collect();
    let idx: Vec<usize> = idx.map(|s| s.to_vec()).unwrap_or(all);
    if geo.rgroup.len() == 1 {
        accum.absorb(ctx, k, v, Some(&idx));
        return;
    }
    ctx.expose(&format!("{stage_tag}.k"), k.clone());
    ctx.expose(&format!("{stage_tag}.v"), v.clone());
    ctx.barrier(&geo.rgroup);
    let group = &geo.rgroup;
    let r = group.len();
    let me = group.iter().position(|&x| x == ctx.rank).unwrap();
    let mut pending = Vec::new();
    for i in 1..r {
        let peer = group[(me + i) % r];
        let hk = ctx.get(peer, &format!("{stage_tag}.k"), flows);
        let hv = ctx.get(peer, &format!("{stage_tag}.v"), flows);
        pending.push((hk, hv));
    }
    accum.absorb(ctx, k, v, Some(&idx));
    for (hk, hv) in pending {
        let kk = ctx.wait_get(hk);
        let vv = ctx.wait_get(hv);
        accum.absorb(ctx, &kk, &vv, Some(&idx));
    }
}

/// Lines 18-35: Pull Q (T stages), Pull KV (T-1 stages), Push O.
fn torus_stages_one_sided(
    ctx: &mut RankCtx,
    p: &SpParams,
    geo: &TorusGeometry,
    q1: Buf,
    k1: Buf,
    v1: Buf,
    flows: usize,
) -> Buf {
    let t_deg = geo.t_degree();
    let t = geo.t;

    // Expose head slices for the torus peers' pulls.
    let q_sl = q1.split(2, t_deg);
    let k_sl = k1.split(2, t_deg);
    let v_sl = v1.split(2, t_deg);
    for i in 0..t_deg {
        ctx.expose(&format!("tq.{i}"), q_sl[i].clone());
        ctx.expose(&format!("tk.{i}"), k_sl[i].clone());
        ctx.expose(&format!("tv.{i}"), v_sl[i].clone());
    }

    // Issue ALL pulls up front, Q before KV (lines 18-21). No barrier:
    // `get` naturally respects the publishers' expose times.
    let mut q_pulls = Vec::new();
    for kk in 1..t_deg {
        let peer = geo.tgroup[(t + t_deg - kk) % t_deg];
        q_pulls.push(ctx.get(peer, &format!("tq.{t}"), flows));
    }
    let mut kv_pulls = Vec::new();
    for kk in 1..t_deg {
        let peer = geo.tgroup[(t + t_deg - kk) % t_deg];
        let hk = ctx.get(peer, &format!("tk.{t}"), flows);
        let hv = ctx.get(peer, &format!("tv.{t}"), flows);
        kv_pulls.push((hk, hv));
    }

    let mut accum = AttnAccum::new(ctx, &q_sl[t], p.chunk);
    let tiles_per_chunk = accum.num_tiles();
    let own_idx: Vec<usize> = (0..tiles_per_chunk).collect();

    // Pull Q stage 1 (line 22): local Q_t x K_t via one-sided ring.
    one_sided_stage_ring(ctx, p, geo, &mut accum, &k_sl[t], &v_sl[t],
                         Some(&own_idx), "sq0", flows);

    // Pull Q stages 2..T (lines 23-26).
    let mut pulled_idx: Vec<usize> = Vec::new();
    for (kk, hq) in q_pulls.into_iter().enumerate() {
        let qc = ctx.wait_get(hq);
        let before = accum.num_tiles();
        accum.push_q(ctx, &qc);
        let idx: Vec<usize> = (before..accum.num_tiles()).collect();
        pulled_idx.extend(&idx);
        one_sided_stage_ring(ctx, p, geo, &mut accum, &k_sl[t], &v_sl[t],
                             Some(&idx), &format!("sq{}", kk + 1), flows);
    }

    // Pull KV stages (lines 27-30): pulled KV x all pulled Q.
    let mut pulled_kv = Vec::new();
    for (kk, (hk, hv)) in kv_pulls.into_iter().enumerate() {
        let kc = ctx.wait_get(hk);
        let vc = ctx.wait_get(hv);
        one_sided_stage_ring(ctx, p, geo, &mut accum, &kc, &vc,
                             Some(&pulled_idx), &format!("skv{kk}"), flows);
        pulled_kv.push((kc, vc));
    }

    // Push O (lines 31-34): pushed while the deferred local compute runs.
    let pulled_out = accum.finish_tiles(ctx, &pulled_idx);
    let mut push_events = Vec::new();
    for kk in 0..t_deg - 1 {
        let peer = geo.tgroup[(t + t_deg - 1 - kk) % t_deg];
        let tiles: Vec<Buf> =
            pulled_out[kk * tiles_per_chunk..(kk + 1) * tiles_per_chunk].to_vec();
        push_events.push(ctx.put(peer, &format!("to.{t}"), Buf::concat(&tiles, 1), flows));
    }
    for (kk, (kc, vc)) in pulled_kv.iter().enumerate() {
        one_sided_stage_ring(ctx, p, geo, &mut accum, kc, vc,
                             Some(&own_idx), &format!("so{kk}"), flows);
    }
    let own_out = Buf::concat(&accum.finish_tiles(ctx, &own_idx), 1);
    for e in push_events {
        ctx.wait_event(e); // quiet before the caller's final barrier
    }

    // Assemble: head slice i comes from torus peer i (slot "to.{i}").
    let mut slices: Vec<Option<Buf>> = vec![None; t_deg];
    slices[t] = Some(own_out);
    for (i, s) in slices.iter_mut().enumerate() {
        if i != t {
            let h = ctx.get(ctx.rank, &format!("to.{i}"), flows);
            *s = Some(ctx.wait_get(h));
        }
    }
    let out: Vec<Buf> = slices.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&out, 2)
}

/// Re-export for the ablation bench: the two-sided torus is in
/// [`super::torus`]; this marker ties the ablation naming together.
pub const COMM_STYLE: CommStyle = CommStyle::OneSided;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, run_in_world, ExecMode};
    use crate::comm::CommWorld;
    use crate::config::{AttnShape, ClusterSpec, SpDegrees};
    use crate::sp::SpAlgo;

    fn params(n: usize, m: usize, pu: usize) -> SpParams {
        let cluster = ClusterSpec::new(n, m);
        let total = n * m;
        SpParams {
            shape: AttnShape::new(1, 65536, 8, 64),
            chunk: 65536 / total,
            mesh: SpAlgo::SwiftFusion.mesh(&cluster, SpDegrees::new(pu, total / pu)),
        }
    }

    fn shard(p: &SpParams) -> Buf {
        Buf::Shape(vec![1, p.shard_len(), p.shape.h, p.shape.d])
    }

    #[test]
    fn shapes_roundtrip() {
        for (n, m, pu) in [(2, 2, 2), (2, 4, 4), (4, 2, 4), (1, 4, 4)] {
            let p = params(n, m, pu);
            let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
                let out =
                    swiftfusion_attention(ctx, &p, shard(&p), shard(&p), shard(&p));
                assert_eq!(out.shape(), shard(&p).shape(), "n={n} m={m} pu={pu}");
            });
            assert!(run.makespan() > 0.0);
        }
    }

    #[test]
    fn exactly_two_global_barriers() {
        // §4.4: only intra-machine synchronizations plus two global
        // barriers per layer.
        let p = params(2, 2, 2);
        let world = CommWorld::new(p.mesh.cluster.clone());
        run_in_world(&world, &ExecMode::Timing, |ctx| {
            swiftfusion_attention(ctx, &p, shard(&p), shard(&p), shard(&p));
        });
        let history = world.barrier_history();
        let total = p.mesh.cluster.total_gpus();
        let global: Vec<_> = history.iter().filter(|g| g.len() == total).collect();
        assert_eq!(global.len(), 2, "exactly two global barriers: {history:?}");
        for g in &history {
            if g.len() < total {
                // every other barrier is intra-machine (ring groups)
                let frac = p.mesh.inter_machine_fraction(g);
                assert_eq!(frac, 0.0, "non-global barrier crosses machines: {g:?}");
            }
        }
    }

    #[test]
    fn swiftfusion_beats_tas_with_multiple_machines() {
        // Ablation claim: overlap + one-sided beats plain TAS.
        let p = params(4, 2, 4);
        let sfu = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            swiftfusion_attention(ctx, &p, shard(&p), shard(&p), shard(&p));
        })
        .makespan();
        let tas = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            SpAlgo::Tas.run(ctx, &p, shard(&p), shard(&p), shard(&p));
        })
        .makespan();
        assert!(sfu < tas, "SFU {sfu} must beat TAS {tas}");
    }

    #[test]
    fn no_two_sided_traffic() {
        // Algorithm 1 is pure one-sided: no rank should ever hold
        // in-flight two-sided transfers (no SM tax anywhere).
        let p = params(2, 2, 2);
        let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            swiftfusion_attention(ctx, &p, shard(&p), shard(&p), shard(&p));
            ctx.clock.two_sided_inflight
        });
        assert!(run.outputs.iter().all(|&x| x == 0));
    }
}
