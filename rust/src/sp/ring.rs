//! Ring Attention (Liu et al., paper §2.2), two-sided NCCL-style.
//!
//! P ranks in a ring; P steps. At step s, rank i sends its *current* KV
//! block to rank (i+1)%P and computes attention of its local Q against
//! that block, merging into the running (O', l, m) state; then it waits
//! for the block arriving from (i-1)%P. Communication volume per rank is
//! `2·(P-1)/P·BLHD ≈ 2·BLHD` — independent of P, the scalability problem
//! the paper's Challenge 1 is about.
//!
//! The send/compute overlap is real (isend → compute → wait), but each
//! step pays the two-sided rendezvous penalty and the in-flight transfer
//! taxes the overlapped compute (SM contention) — both captured by the
//! comm layer, both eliminated in the one-sided variant
//! ([`ring_attention_one_sided`], Algorithm 1's RINGATTN).

use crate::cluster::exec::RankCtx;
use crate::comm::Buf;

use super::tiles::AttnAccum;
use super::SpParams;

/// Ring Attention over an explicit `group` of ranks (increasing-rank
/// order). `q`,`k`,`v` are this rank's shards within the group's slice of
/// the sequence; `accum` may already hold q tiles (USP reuses this).
/// `flows` is the NIC fair-share divisor for inter-machine hops.
pub fn ring_attention_group(
    ctx: &mut RankCtx,
    accum: &mut AttnAccum,
    group: &[usize],
    k: Buf,
    v: Buf,
    flows: usize,
) {
    let r = group.len();
    let me = group
        .iter()
        .position(|&x| x == ctx.rank)
        .expect("rank not in its ring group");
    let next = group[(me + 1) % r];
    let prev = group[(me + r - 1) % r];

    let mut cur_k = k;
    let mut cur_v = v;
    for step in 0..r {
        let last = step == r - 1;
        // launch the next exchange before computing (overlap): send our
        // current block onward AND post the receive for the incoming one
        // (NCCL-style early-posted irecv progresses during compute)
        let pending = if !last {
            let tag_k = format!("ring.k.{step}");
            let tag_v = format!("ring.v.{step}");
            let sk = ctx.isend(next, &tag_k, cur_k.clone());
            let sv = ctx.isend(next, &tag_v, cur_v.clone());
            let rk = ctx.irecv(prev, &tag_k, flows);
            let rv = ctx.irecv(prev, &tag_v, flows);
            Some((sk, sv, rk, rv))
        } else {
            None
        };

        accum.absorb(ctx, &cur_k, &cur_v, None);

        if let Some((sk, sv, rk, rv)) = pending {
            cur_k = ctx.wait_get(rk);
            cur_v = ctx.wait_get(rv);
            ctx.wait_send(sk);
            ctx.wait_send(sv);
        }
    }
}

/// One-sided Ring Attention (Algorithm 1, RINGATTN procedure): instead of
/// neighbor-to-neighbor sends, every rank *pulls* the KV shard of rank
/// (me+i)%R directly from its window — no rendezvous, no per-step global
/// sync. Peers must have `expose`d their KV under `slot_prefix` already.
pub fn ring_attention_one_sided(
    ctx: &mut RankCtx,
    accum: &mut AttnAccum,
    group: &[usize],
    k: Buf,
    v: Buf,
    slot_prefix: &str,
    flows: usize,
) {
    let r = group.len();
    let me = group
        .iter()
        .position(|&x| x == ctx.rank)
        .expect("rank not in its ring group");

    // Issue ALL pulls up front (Algorithm 1 line 4 issues pull i at step i;
    // issuing eagerly maximizes overlap and is what the stream queue does).
    let mut pending = Vec::new();
    for i in 1..r {
        let peer = group[(me + i) % r];
        let hk = ctx.get(peer, &format!("{slot_prefix}.k"), flows);
        let hv = ctx.get(peer, &format!("{slot_prefix}.v"), flows);
        pending.push((hk, hv));
    }

    // Step 0: local block.
    accum.absorb(ctx, &k, &v, None);
    // Steps 1..R: consume pulls as they complete.
    for (hk, hv) in pending {
        let kk = ctx.wait_get(hk);
        let vv = ctx.wait_get(hv);
        accum.absorb(ctx, &kk, &vv, None);
    }
}

/// Full-mesh Ring Attention: the classic baseline. Each rank keeps all H
/// heads and its L/P sequence shard. "Full mesh" means the rank set of
/// `p.mesh` — on a carved sub-mesh the ring stays inside the partition.
pub fn ring_attention_full(ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
    let group: Vec<usize> = p.mesh.ranks();
    let flows = ctx.nic_flows(&group);
    let mut accum = AttnAccum::new(ctx, &q, p.chunk);
    ring_attention_group(ctx, &mut accum, &group, k, v, flows);
    accum.finish(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::cluster::Placement;
    use crate::config::{AttnShape, ClusterSpec, SpDegrees};
    use crate::sp::SpAlgo;

    fn params(n: usize, m: usize) -> SpParams {
        let cluster = ClusterSpec::new(n, m);
        let p = n * m;
        SpParams {
            shape: AttnShape::new(1, 128, 4, 16),
            chunk: 128 / p,
            mesh: SpAlgo::Ring.mesh(&cluster, SpDegrees::new(1, p)),
        }
    }

    fn shard(p: &SpParams) -> Buf {
        Buf::Shape(vec![1, p.shard_len(), p.shape.h, p.shape.d])
    }

    #[test]
    fn ring_timing_runs_and_costs_time() {
        let p = params(2, 2);
        let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            let out = ring_attention_full(ctx, &p, shard(&p), shard(&p), shard(&p));
            assert_eq!(out.shape(), &[1, 32, 4, 16]);
            ctx.clock.now
        });
        assert!(run.makespan() > 0.0);
        // all ranks end within one step of each other (ring symmetry)
        let min = run.outputs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(run.makespan() / min < 1.5);
    }

    #[test]
    fn ring_volume_independent_of_p() {
        // Challenge 1: per-rank comm time should NOT shrink with more
        // machines (volume stays ~2·BLHD). Compare makespan comm fraction.
        let t2 = {
            let p = params(2, 1);
            run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
                ring_attention_full(ctx, &p, shard(&p), shard(&p), shard(&p));
            })
            .makespan()
        };
        let t4 = {
            let p = params(4, 1);
            run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
                ring_attention_full(ctx, &p, shard(&p), shard(&p), shard(&p));
            })
            .makespan()
        };
        // compute shrinks 4x per rank from P=2 to P=4 but comm doesn't:
        // the inter-machine ring keeps latency high. t4 must be well above
        // a perfect-scaling t2/2.
        assert!(t4 > t2 / 2.0 * 1.05, "t2={t2} t4={t4}");
    }

    #[test]
    fn one_sided_ring_skips_rendezvous() {
        // Same collective both ways; one-sided must be faster (no
        // two_sided_sync, no SM tax).
        let cluster = ClusterSpec::new(2, 2);
        let p = SpParams {
            shape: AttnShape::new(1, 128, 4, 16),
            chunk: 32,
            mesh: crate::cluster::Mesh2D::new(
                cluster.clone(),
                SpDegrees::new(1, 4),
                Placement::UlyssesInter,
            ),
        };
        let group: Vec<usize> = (0..4).collect();
        let two = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let mut acc = AttnAccum::new(ctx, &shard(&p), p.chunk);
            ring_attention_group(ctx, &mut acc, &group, shard(&p), shard(&p), 2);
            acc.finish(ctx);
        })
        .makespan();
        let one = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            ctx.expose("rg.k", shard(&p));
            ctx.expose("rg.v", shard(&p));
            ctx.barrier_all();
            let mut acc = AttnAccum::new(ctx, &shard(&p), p.chunk);
            ring_attention_one_sided(ctx, &mut acc, &group, shard(&p), shard(&p), "rg", 2);
            acc.finish(ctx);
        })
        .makespan();
        assert!(one < two, "one-sided {one} should beat two-sided {two}");
    }
}
