//! Ulysses Attention (DeepSpeed-Ulysses, paper §2.2), two-sided.
//!
//! Exploits head-independence: three all-to-alls turn sequence-sharded
//! Q/K/V `[B, L/P, H, D]` into head-sharded `[B, L, H/P, D]`; attention is
//! then fully local; a fourth all-to-all restores the output layout.
//! Communication volume per rank is `4·(P-1)/P²·BLHD ≈ 4·BLHD/P` — it
//! *shrinks* with P (unlike Ring), but the all-to-alls are atomic and not
//! overlapped with compute (Challenge 2), and `P` must divide `H`.

use crate::cluster::exec::RankCtx;
use crate::comm::Buf;

use super::tiles::AttnAccum;
use super::SpParams;

/// Two-sided all-to-all over `group`: scatter `axis_split` of the local
/// buffer to peers, gather peers' pieces concatenated along `axis_cat`.
/// This is the seq↔head redistribution both directions need:
///  * QKV forward: split heads (axis 2), gather sequence (axis 1);
///  * O backward:  split sequence (axis 1), gather heads (axis 2).
///
/// The whole exchange is atomic — compute cannot start until every piece
/// has arrived (what Torus Attention later breaks up).
pub fn all_to_all(
    ctx: &mut RankCtx,
    group: &[usize],
    buf: &Buf,
    axis_split: usize,
    axis_cat: usize,
    tag: &str,
    flows: usize,
) -> Buf {
    let u = group.len();
    let me = group
        .iter()
        .position(|&x| x == ctx.rank)
        .expect("rank not in group");
    if u == 1 {
        return buf.clone();
    }
    let parts = buf.split(axis_split, u);

    // Launch all sends, then receive everything, then complete sends:
    // the NCCL grouped-call pattern.
    let mut sends = Vec::new();
    for (j, part) in parts.iter().enumerate() {
        if j != me {
            sends.push(ctx.isend(group[j], &format!("a2a.{tag}.{j}"), part.clone()));
        }
    }
    let mut gathered: Vec<Option<Buf>> = vec![None; u];
    gathered[me] = Some(parts[me].clone());
    for (j, &peer) in group.iter().enumerate() {
        if j != me {
            gathered[j] = Some(ctx.wait_recv(peer, &format!("a2a.{tag}.{me}"), flows));
        }
    }
    for h in sends {
        ctx.wait_send(h);
    }
    let pieces: Vec<Buf> = gathered.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&pieces, axis_cat)
}

/// Local attention after the QKV all-to-alls: q/k/v are `[B, Lg, g, D]`;
/// chunked through the tile kernel (multiple KV tiles, carried state) —
/// identical numerics to one big attention call.
pub fn local_attention(ctx: &mut RankCtx, p: &SpParams, q: &Buf, k: &Buf, v: &Buf) -> Buf {
    let mut accum = AttnAccum::new(ctx, q, p.chunk);
    accum.absorb(ctx, k, v, None);
    accum.finish(ctx)
}

/// Full Ulysses Attention over an explicit group (increasing-rank order).
pub fn ulysses_attention_group(
    ctx: &mut RankCtx,
    p: &SpParams,
    group: &[usize],
    q: Buf,
    k: Buf,
    v: Buf,
    tag: &str,
) -> Buf {
    let flows = ctx.nic_flows(group);
    let qg = all_to_all(ctx, group, &q, 2, 1, &format!("{tag}.q"), flows);
    let kg = all_to_all(ctx, group, &k, 2, 1, &format!("{tag}.k"), flows);
    let vg = all_to_all(ctx, group, &v, 2, 1, &format!("{tag}.v"), flows);
    let o = local_attention(ctx, p, &qg, &kg, &vg);
    all_to_all(ctx, group, &o, 1, 2, &format!("{tag}.o"), flows)
}

/// Mesh-wide Ulysses (the paper's single-machine baseline and the M=1
/// degenerate case of every method). On a carved sub-mesh the all-to-alls
/// stay inside the partition.
pub fn ulysses_attention(ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
    let group: Vec<usize> = p.mesh.ranks();
    assert_eq!(
        p.shape.h % group.len(),
        0,
        "Ulysses requires P | H (paper §2.2)"
    );
    ulysses_attention_group(ctx, p, &group, q, k, v, "ul")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::config::{AttnShape, ClusterSpec, SpDegrees};
    use crate::sp::SpAlgo;
    use crate::tensor::Tensor;

    fn params(n: usize, m: usize) -> SpParams {
        let cluster = ClusterSpec::new(n, m);
        let p = n * m;
        SpParams {
            // paper-regime shape: long sequence so bandwidth terms, not
            // latency constants, dominate (timing mode: tensors are stubs)
            shape: AttnShape::new(1, 65536, 4, 64),
            chunk: 65536 / p,
            mesh: SpAlgo::Ulysses.mesh(&cluster, SpDegrees::new(p, 1)),
        }
    }

    #[test]
    fn all_to_all_shapes() {
        let p = params(2, 2);
        let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
            let local = Buf::Shape(vec![1, 16384, 4, 64]);
            let group: Vec<usize> = (0..4).collect();
            let g = all_to_all(ctx, &group, &local, 2, 1, "t", 2);
            assert_eq!(g.shape(), &[1, 65536, 1, 64]);
            let back = all_to_all(ctx, &group, &g, 1, 2, "t2", 2);
            assert_eq!(back.shape(), &[1, 16384, 4, 64]);
        });
        assert!(run.makespan() > 0.0);
    }

    #[test]
    fn all_to_all_permutes_real_data_losslessly() {
        // 2 ranks, real tensors: verify scatter/gather is a permutation
        // (no element lost or duplicated) and the roundtrip is identity.
        let cluster = ClusterSpec::new(1, 2);
        let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let t = Tensor::random(&[1, 4, 2, 2], 100 + ctx.rank as u64);
            let local = Buf::Real(t.clone());
            let group = vec![0, 1];
            let g = all_to_all(ctx, &group, &local, 2, 1, "x", 1);
            let back = all_to_all(ctx, &group, &g, 1, 2, "y", 1);
            (t, back.into_tensor())
        });
        for (orig, back) in &run.outputs {
            assert_eq!(orig, back, "a2a roundtrip must be identity");
        }
    }

    #[test]
    fn singleton_group_is_identity() {
        let cluster = ClusterSpec::new(1, 1);
        run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let b = Buf::Shape(vec![1, 8, 2, 4]);
            let out = all_to_all(ctx, &[0], &b, 2, 1, "s", 1);
            assert_eq!(out.shape(), b.shape());
        });
    }

    #[test]
    fn ulysses_comm_shrinks_with_p() {
        // Ulysses volume ~ 4·BLHD/P: the non-compute part of the makespan
        // should shrink as P grows (contrast with ring_volume test).
        let comm_frac = |n: usize| {
            let p = params(n, 1);
            let run = run_cluster(&p.mesh.cluster.clone(), &ExecMode::Timing, |ctx| {
                let s = Buf::Shape(vec![1, p.shard_len(), 4, 64]);
                ulysses_attention(ctx, &p, s.clone(), s.clone(), s);
            });
            let (_c, w, s, _o) = run.mean_breakdown();
            w + s
        };
        let w2 = comm_frac(2);
        let w4 = comm_frac(4);
        assert!(w4 < w2, "ulysses comm wait must shrink with P: {w2} -> {w4}");
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn ulysses_requires_p_divides_h() {
        // H=4 but P=8
        let cluster = ClusterSpec::new(4, 2);
        let p = SpParams {
            shape: AttnShape::new(1, 128, 4, 16),
            chunk: 16,
            mesh: SpAlgo::Ulysses.mesh(&cluster, SpDegrees::new(8, 1)),
        };
        run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![1, 16, 4, 16]);
            ulysses_attention(ctx, &p, s.clone(), s.clone(), s);
        });
    }
}
