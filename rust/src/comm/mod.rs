//! Inter-rank communication: two-sided (NCCL analog) and one-sided
//! (NVSHMEM analog) primitives over real shared-memory channels, with
//! virtual-time cost accounting.
//!
//! The data plane is *real*: tensors actually move between rank threads,
//! so numerics are exact. The time plane is *simulated*: every transfer
//! charges the α–β link model ([`crate::config::NetSpec`]) onto the
//! participating ranks' [`RankClock`]s. The two libraries differ exactly
//! as the paper's Challenge 3 describes:
//!
//! * **two-sided** ([`CommWorld::wait_recv`]): the receiver cannot start
//!   until the sender has arrived (rendezvous, Fig. 4) — both sides pay a
//!   sync penalty and the *sender is blocked until the transfer completes*;
//!   in-flight two-sided transfers also tax overlapping compute (SM
//!   contention, tracked via `RankClock::two_sided_inflight`).
//! * **one-sided** ([`CommWorld::put`] / [`CommWorld::get`]): transfers
//!   are asynchronous against windows (exposed buffers); only explicit
//!   waits and barriers synchronize. No rendezvous, no SM tax (the
//!   NVSHMEM-on-stream / driver-copy path of Appendix A).
//!
//! Determinism: completion times depend only on (sender issue time,
//! receiver issue time, link model, per-rank egress/ingress queues) — not
//! on wall-clock thread interleaving.
//!
//! ## One-sided window semantics (the contract PipeFusion relies on)
//!
//! Windows are keyed by `(owner rank, slot name)`; ranks re-expose slots
//! freely, and [`crate::cluster::exec::RankCtx`] prefixes every slot
//! with its *window epoch* so successive collectives can never read a
//! stale window from an earlier layer by accident
//! ([`crate::cluster::exec::RankCtx::next_epoch`]). Within an epoch the
//! guarantees are exactly NVSHMEM's:
//!
//! * a [`CommWorld::get`] observes the **whole** buffer most recently
//!   published under the slot (publication is atomic — never a torn or
//!   half-written tensor), and its virtual completion respects the
//!   publisher's `publish_time`;
//! * there is **no implicit global ordering**: only explicit waits,
//!   fences, and [`CommWorld::barrier`] synchronize, so a rank may
//!   legally keep computing against an *older local copy* of data a
//!   peer has since refreshed.
//!
//! That last point is a feature, not a hazard: the displaced patch
//! pipeline ([`crate::sp::pipefusion`]) deliberately serves off-stage KV
//! from the previous diffusion step's activations (one-step-stale), and
//! its correctness argument — an oracle-exact synchronous warm-up step,
//! then staleness bounded by one step of input drift — depends only on
//! the two guarantees above, never on inter-rank timing.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

use crate::cluster::clock::{RankClock, TimeKind};
use crate::config::{ClusterSpec, NetSpec};
use crate::tensor::Tensor;

/// A buffer that is a real tensor (numeric mode) or shape-only stub
/// (timing mode, for paper-scale simulations where materializing tensors
/// is impossible). All structural ops work in both modes.
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    Real(Tensor),
    Shape(Vec<usize>),
}

impl Buf {
    pub fn shape(&self) -> &[usize] {
        match self {
            Buf::Real(t) => t.shape(),
            Buf::Shape(s) => s,
        }
    }

    pub fn bytes(&self) -> f64 {
        self.shape().iter().product::<usize>() as f64 * 4.0
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Buf::Real(_))
    }

    pub fn tensor(&self) -> &Tensor {
        match self {
            Buf::Real(t) => t,
            Buf::Shape(s) => panic!("timing-mode Buf{s:?} has no tensor data"),
        }
    }

    pub fn into_tensor(self) -> Tensor {
        match self {
            Buf::Real(t) => t,
            Buf::Shape(s) => panic!("timing-mode Buf{s:?} has no tensor data"),
        }
    }

    pub fn slice(&self, axis: usize, start: usize, end: usize) -> Buf {
        match self {
            Buf::Real(t) => Buf::Real(t.slice(axis, start, end).expect("slice")),
            Buf::Shape(s) => {
                let mut s = s.clone();
                s[axis] = end - start;
                Buf::Shape(s)
            }
        }
    }

    /// Split along `axis` into `parts` equal pieces. Degenerate inputs
    /// (zero parts, out-of-range axis, a dimension the parts don't
    /// divide) fail with a descriptive assertion rather than an index
    /// panic deep inside the tensor layer.
    pub fn split(&self, axis: usize, parts: usize) -> Vec<Buf> {
        assert!(parts > 0, "Buf::split of {:?} into zero parts", self.shape());
        assert!(
            axis < self.shape().len(),
            "Buf::split axis {axis} out of bounds for shape {:?}",
            self.shape()
        );
        assert_eq!(
            self.shape()[axis] % parts,
            0,
            "Buf::split axis {axis} of {:?} into {parts} unequal parts",
            self.shape()
        );
        match self {
            Buf::Real(t) => t
                .split(axis, parts)
                .expect("split checked above")
                .into_iter()
                .map(Buf::Real)
                .collect(),
            Buf::Shape(s) => {
                let mut out = s.clone();
                out[axis] /= parts;
                vec![Buf::Shape(out); parts]
            }
        }
    }

    /// Concatenate along `axis`. An empty buffer list, an out-of-range
    /// axis, or mismatched off-axis dimensions fail with a descriptive
    /// assertion rather than an index panic.
    pub fn concat(bufs: &[Buf], axis: usize) -> Buf {
        assert!(!bufs.is_empty(), "Buf::concat of an empty buffer list");
        let first = bufs[0].shape();
        assert!(
            axis < first.len(),
            "Buf::concat axis {axis} out of bounds for shape {first:?}"
        );
        for b in bufs {
            let s = b.shape();
            let compatible = s.len() == first.len()
                && s.iter()
                    .zip(first)
                    .enumerate()
                    .all(|(i, (a, b))| i == axis || a == b);
            assert!(
                compatible,
                "Buf::concat axis {axis} shape mismatch: {s:?} vs {first:?}"
            );
        }
        if bufs.iter().all(|b| b.is_real()) {
            let ts: Vec<&Tensor> = bufs.iter().map(|b| b.tensor()).collect();
            Buf::Real(Tensor::concat(&ts, axis).expect("concat checked above"))
        } else {
            let mut s = first.to_vec();
            s[axis] = bufs.iter().map(|b| b.shape()[axis]).sum();
            Buf::Shape(s)
        }
    }
}

/// Completion handle for an async operation; `done` is virtual time.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub done: f64,
}

/// Handle for a pending one-sided get (pull): data + completion time.
#[derive(Debug)]
pub struct GetHandle {
    pub buf: Buf,
    pub done: f64,
}

/// Handle for a pending two-sided send: resolved by the receiver.
#[derive(Debug)]
pub struct SendHandle {
    key: MsgKey,
    seq: u64,
}

type MsgKey = (usize, usize, String); // (src, dst, tag)

struct TwoSidedMsg {
    buf: Buf,
    sender_ready: f64,
    seq: u64,
    /// set by the receiver once the rendezvous completes
    done: Option<f64>,
}

struct WindowEntry {
    buf: Buf,
    publish_time: f64,
}

#[derive(Default)]
struct BarrierState {
    generation: u64,
    arrived: usize,
    max_time: f64,
    release_time: f64,
}

/// Per-rank transfer-volume counters (bytes), split by link class and
/// direction. The Appendix-D analysis tests compare these *measured*
/// volumes against the paper's closed-form formulas.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    pub intra_in: f64,
    pub intra_out: f64,
    pub inter_in: f64,
    pub inter_out: f64,
}

/// Aggregate comm observability of one (or many, via [`Self::absorb`])
/// world runs: the serve report's `comm` section and the comm-opt
/// bench's notes. Inter-machine byte counters are **wire** bytes —
/// compressed hops ([`NetSpec::inter_compress`]) count what crossed the
/// NIC, not the logical payload.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    /// Per-rank [`Traffic`] counters summed over all ranks.
    pub traffic: Traffic,
    /// Wire-seconds NICs were busy under scheduled mode
    /// ([`NetSpec::nic_schedule`]); zero in constant fair-share mode.
    pub nic_busy: f64,
    /// Inter-machine transfers priced at the fused CFG-pair rate
    /// ([`CommWorld::set_cfg_fused`]).
    pub fused_transfers: u64,
}

impl CommStats {
    /// Fold another run's stats into this accumulator.
    pub fn absorb(&mut self, other: &CommStats) {
        self.traffic.intra_in += other.traffic.intra_in;
        self.traffic.intra_out += other.traffic.intra_out;
        self.traffic.inter_in += other.traffic.inter_in;
        self.traffic.inter_out += other.traffic.inter_out;
        self.nic_busy += other.nic_busy;
        self.fused_transfers += other.fused_transfers;
    }
}

struct Shared {
    mailbox: HashMap<MsgKey, Vec<TwoSidedMsg>>,
    windows: HashMap<(usize, String), WindowEntry>,
    barriers: HashMap<Vec<usize>, BarrierState>,
    /// every completed barrier's (sorted) group — the Algorithm-1
    /// synchronization-count tests read this
    barrier_history: Vec<Vec<usize>>,
    /// resident window bytes per rank + high-water mark (Fig. 7 memory)
    window_bytes: Vec<f64>,
    peak_window_bytes: Vec<f64>,
    traffic: Vec<Traffic>,
    next_seq: u64,
    /// Per-rank NIC lane timelines for contention-aware chunk
    /// scheduling ([`crate::config::NetSpec::nic_schedule`]): virtual
    /// time each rank's ingress/egress NIC share is next free. A lane
    /// is only ever touched by transfers *its own rank issues* (gets
    /// and irecvs for ingress, puts for egress), so the values are
    /// independent of wall-clock thread interleaving — the same
    /// per-rank-ownership argument the [`RankClock`] queues rely on.
    nic_in_free: Vec<f64>,
    nic_out_free: Vec<f64>,
    /// Wire-seconds each rank's transfers occupied its NIC under
    /// scheduled mode (chunk time only, no α) — observability for the
    /// serve report's comm section.
    nic_busy: Vec<f64>,
    /// Inter-machine transfers priced at the fused (CFG-pair) rate per
    /// rank.
    fused_inter: Vec<u64>,
    /// Set by the plan layer when the carved plan's CFG branch groups
    /// have identical collective footprints
    /// ([`crate::cluster::plan::ParallelPlan::cfg_fusible`]): the two
    /// branches' same-shape inter-machine transfers move as one
    /// scheduled flow, so each branch pays half the per-transfer α and
    /// half the two-sided rendezvous.
    cfg_fused: bool,
}

impl Shared {
    fn record_transfer(&mut self, src: usize, dst: usize, bytes: f64, inter: bool) {
        if inter {
            self.traffic[src].inter_out += bytes;
            self.traffic[dst].inter_in += bytes;
        } else {
            self.traffic[src].intra_out += bytes;
            self.traffic[dst].intra_in += bytes;
        }
    }
}

/// The communication world shared by all ranks of one cluster run.
pub struct CommWorld {
    pub cluster: ClusterSpec,
    state: Mutex<Shared>,
    cond: Condvar,
}

impl CommWorld {
    pub fn new(cluster: ClusterSpec) -> Self {
        let n = cluster.total_gpus();
        Self {
            cluster,
            state: Mutex::new(Shared {
                mailbox: HashMap::new(),
                windows: HashMap::new(),
                barriers: HashMap::new(),
                barrier_history: Vec::new(),
                window_bytes: vec![0.0; n],
                peak_window_bytes: vec![0.0; n],
                traffic: vec![Traffic::default(); n],
                next_seq: 0,
                nic_in_free: vec![0.0; n],
                nic_out_free: vec![0.0; n],
                nic_busy: vec![0.0; n],
                fused_inter: vec![0; n],
                cfg_fused: false,
            }),
            cond: Condvar::new(),
        }
    }

    fn net(&self) -> &NetSpec {
        &self.cluster.net
    }

    /// α–β transfer duration between two ranks; `flows` = concurrent flows
    /// sharing the NIC for inter-machine transfers (from the algorithm's
    /// communication structure; see DESIGN.md §2 on static fair-share).
    pub fn transfer_time(&self, src: usize, dst: usize, bytes: f64, flows: usize) -> f64 {
        let n = self.net();
        if self.cluster.same_machine(src, dst) {
            n.intra_lat + bytes / n.intra_bw
        } else {
            n.inter_lat + bytes / n.inter_bw_per_flow(flows)
        }
    }

    /// Mark this world's run as CFG-fused (set once, before ranks run):
    /// inter-machine transfers price at the fused-pair rate — half the
    /// per-transfer α and half the two-sided rendezvous — because the
    /// two CFG branches' identical-shape collectives move as one
    /// scheduled flow. The plan layer gates this on
    /// [`crate::cluster::plan::ParallelPlan::cfg_fusible`].
    pub fn set_cfg_fused(&self, on: bool) {
        self.state.lock().unwrap().cfg_fused = on;
    }

    /// Price one **inter-machine** hop onto `rank`'s NIC and record its
    /// wire traffic; returns `(done, dur)` where `dur` is the occupancy
    /// the issuing kernel observes (the two-sided stream-block charge).
    /// `earliest` is when the transfer may start (publish time or
    /// rendezvous), `tax` the SM-contention multiplier (two-sided
    /// only), `egress` which of `rank`'s lanes it occupies.
    ///
    /// With [`NetSpec::nic_schedule`] off this is the legacy model —
    /// the constant fair-share α–β duration chained through the rank
    /// clock's egress/ingress queue, bit-identical to the pre-pass
    /// numbers when compression and fusion are off too. On, transfers
    /// are TDMA-scheduled on the rank's lane: each chunk moves at
    /// *full* NIC bandwidth in its round-robin slot (`flows` slots per
    /// period, this rank staggered by its on-machine index), so a
    /// burst's early chunks land ~`flows`× sooner and queued chunks
    /// stop re-paying α, while aggregate NIC throughput is conserved
    /// (the lane frees at `flows` chunk-times per transfer).
    #[allow(clippy::too_many_arguments)]
    fn inter_hop(
        &self,
        st: &mut Shared,
        clock: &mut RankClock,
        rank: usize,
        peer: usize,
        bytes: f64,
        flows: usize,
        earliest: f64,
        tax: f64,
        egress: bool,
    ) -> (f64, f64) {
        let n = self.net();
        let wire = bytes * n.inter_compress;
        let mut lat = n.inter_lat;
        if st.cfg_fused {
            lat *= 0.5;
            st.fused_inter[rank] += 1;
        }
        let (src, dst) = if egress { (rank, peer) } else { (peer, rank) };
        st.record_transfer(src, dst, wire, true);
        if !n.nic_schedule {
            let dur = (lat + wire / n.inter_bw_per_flow(flows)) * (1.0 + tax);
            let (_, done) = if egress {
                clock.reserve_egress(earliest, dur)
            } else {
                clock.reserve_ingress(earliest, dur)
            };
            return (done, dur);
        }
        // chunk wire time at full NIC bandwidth; the SM tax slows the
        // copy kernel feeding the NIC, not the queueing discipline
        let c = (wire / n.inter_bw) * (1.0 + tax);
        let f = flows.max(1);
        let slot = (rank % self.cluster.gpus_per_machine) % f;
        let lane = if egress { &mut st.nic_out_free[rank] } else { &mut st.nic_in_free[rank] };
        // a fresh burst staggers by this rank's TDMA slot; a queued
        // chunk waits for the lane's next period
        let start = if earliest >= *lane { earliest + slot as f64 * c } else { *lane };
        *lane = start + f as f64 * c;
        st.nic_busy[rank] += c;
        let dur = lat * (1.0 + tax) + c;
        (start + dur, dur)
    }

    /// Quantize a real payload to the wire precision of a compressed
    /// inter-machine hop ([`NetSpec::inter_compress`]): a uniform
    /// symmetric grid over the buffer's max magnitude at
    /// `32 × ratio` bits, so the timing model's wire-byte multiplier
    /// and the numeric error the property tests bound come from the
    /// same knob. Shape-only (timing mode) buffers pass through.
    fn maybe_compress(&self, buf: Buf) -> Buf {
        let ratio = self.net().inter_compress;
        if ratio >= 1.0 {
            return buf;
        }
        let Buf::Real(t) = buf else { return buf };
        let bits = (32.0 * ratio).round().max(2.0);
        let levels = (2f64.powf(bits - 1.0) - 1.0) as f32;
        let amax = t.data().iter().fold(0f32, |a, v| a.max(v.abs()));
        if amax == 0.0 {
            return Buf::Real(t);
        }
        let scale = amax / levels;
        let data = t.data().iter().map(|v| (v / scale).round() * scale).collect();
        Buf::Real(Tensor::new(t.shape().to_vec(), data).expect("same shape"))
    }

    // -----------------------------------------------------------------
    // Two-sided (NCCL analog)
    // -----------------------------------------------------------------

    /// Non-blocking send. The message is deposited with the sender's
    /// current virtual time; the *receiver* resolves the rendezvous.
    /// The sender must later `wait_send` (NCCL's implicit completion).
    pub fn isend(
        &self,
        clock: &mut RankClock,
        src: usize,
        dst: usize,
        tag: &str,
        buf: Buf,
    ) -> SendHandle {
        let key = (src, dst, tag.to_string());
        let mut st = self.state.lock().unwrap();
        let seq = st.next_seq;
        st.next_seq += 1;
        st.mailbox.entry(key.clone()).or_default().push(TwoSidedMsg {
            buf,
            sender_ready: clock.now,
            seq,
            done: None,
        });
        clock.advance(1e-6, TimeKind::Overhead); // issue cost
        clock.two_sided_inflight += 1;
        self.cond.notify_all();
        SendHandle { key, seq }
    }

    /// Post a receive (NCCL irecv analog): rendezvous with the matching
    /// send, compute the completion time (respecting this rank's ingress
    /// queue), and return a handle — the transfer then progresses "in the
    /// background" so posting early and computing before the wait gives
    /// real overlap, exactly like NCCL on a comm stream. Blocks (wall)
    /// until the matching send was posted.
    pub fn irecv(
        &self,
        clock: &mut RankClock,
        src: usize,
        dst: usize,
        tag: &str,
        flows: usize,
    ) -> GetHandle {
        let key = (src, dst, tag.to_string());
        let mut st = self.state.lock().unwrap();
        loop {
            let msgs = st.mailbox.entry(key.clone()).or_default();
            if let Some(pos) = msgs.iter().position(|m| m.done.is_none()) {
                let sender_ready = msgs[pos].sender_ready;
                let bytes = msgs[pos].buf.bytes();
                let inter = !self.cluster.same_machine(src, dst);
                let (done, dur) = if inter {
                    // rendezvous: transfer starts when BOTH sides are
                    // ready, plus the two-sided sync penalty (Fig. 4) —
                    // paid once for the pair when CFG fusion is on.
                    // kernel-based two-sided transfers burn SMs
                    // (Challenge 3): modelled as an effective-bandwidth
                    // loss on the transfer inside `inter_hop`.
                    let sync = if st.cfg_fused {
                        self.net().two_sided_sync * 0.5
                    } else {
                        self.net().two_sided_sync
                    };
                    let earliest = sender_ready.max(clock.now) + sync;
                    self.inter_hop(
                        &mut st,
                        clock,
                        dst,
                        src,
                        bytes,
                        flows,
                        earliest,
                        self.net().sm_tax,
                        false,
                    )
                } else {
                    let earliest = sender_ready.max(clock.now) + self.net().two_sided_sync;
                    let dur = self.transfer_time(src, dst, bytes, flows)
                        * (1.0 + self.net().sm_tax);
                    let (_, done) = clock.reserve_ingress(earliest, dur);
                    st.record_transfer(src, dst, bytes, false);
                    (done, dur)
                };
                let msgs = st.mailbox.entry(key.clone()).or_default();
                let msg = &mut msgs[pos];
                msg.done = Some(done);
                let buf = msg.buf.clone();
                let buf = if inter { self.maybe_compress(buf) } else { buf };
                // the NCCL kernel occupies stream slots: a fraction of
                // the transfer blocks the issuing rank outright
                clock.advance(
                    dur * self.net().two_sided_stream_block,
                    TimeKind::Sync,
                );
                clock.advance(1e-6, TimeKind::Overhead);
                self.cond.notify_all();
                return GetHandle { buf, done };
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Blocking receive: `irecv` + wait fused.
    pub fn wait_recv(
        &self,
        clock: &mut RankClock,
        src: usize,
        dst: usize,
        tag: &str,
        flows: usize,
    ) -> Buf {
        let h = self.irecv(clock, src, dst, tag, flows);
        self.wait_get(clock, h)
    }

    /// Complete a send: blocks (wall) until the receiver resolved it, then
    /// advances the sender to the completion time (the sender-side
    /// synchronization the paper's Challenge 3 complains about).
    pub fn wait_send(&self, clock: &mut RankClock, handle: SendHandle) {
        let mut st = self.state.lock().unwrap();
        loop {
            let msgs = st.mailbox.entry(handle.key.clone()).or_default();
            if let Some(pos) = msgs.iter().position(|m| m.seq == handle.seq) {
                if let Some(done) = msgs[pos].done {
                    msgs.remove(pos);
                    clock.advance_to(done, TimeKind::Sync);
                    clock.two_sided_inflight = clock.two_sided_inflight.saturating_sub(1);
                    return;
                }
            } else {
                panic!("wait_send: message vanished (double wait?)");
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    // -----------------------------------------------------------------
    // One-sided (NVSHMEM analog)
    // -----------------------------------------------------------------

    /// Publish a buffer into this rank's own window (symmetric-heap
    /// registration): remote ranks may `get` it from `publish_time` on.
    pub fn expose(&self, clock: &RankClock, owner: usize, slot: &str, buf: Buf) {
        let mut st = self.state.lock().unwrap();
        let bytes = buf.bytes();
        st.windows
            .insert((owner, slot.to_string()), WindowEntry { buf, publish_time: clock.now });
        st.window_bytes[owner] += bytes;
        st.peak_window_bytes[owner] = st.peak_window_bytes[owner].max(st.window_bytes[owner]);
        self.cond.notify_all();
    }

    /// One-sided push (`nvshmemx_putmem_on_stream`): write into `dst`'s
    /// window slot. Asynchronous: the sender pays only the issue overhead;
    /// the data becomes visible at the computed arrival time. Returns the
    /// completion event (for quiet/fence semantics).
    pub fn put(
        &self,
        clock: &mut RankClock,
        src: usize,
        dst: usize,
        slot: &str,
        buf: Buf,
        flows: usize,
    ) -> Event {
        let bytes = buf.bytes();
        let now = clock.now;
        let mut st = self.state.lock().unwrap();
        let (done, buf) = if self.cluster.same_machine(src, dst) {
            let dur = self.transfer_time(src, dst, bytes, flows);
            let (_, done) = clock.reserve_egress(now, dur);
            st.record_transfer(src, dst, bytes, false);
            (done, buf)
        } else {
            let (done, _) =
                self.inter_hop(&mut st, clock, src, dst, bytes, flows, now, 0.0, true);
            (done, self.maybe_compress(buf))
        };
        st.windows
            .insert((dst, slot.to_string()), WindowEntry { buf, publish_time: done });
        st.window_bytes[dst] += bytes;
        st.peak_window_bytes[dst] = st.peak_window_bytes[dst].max(st.window_bytes[dst]);
        clock.advance(1e-6, TimeKind::Overhead);
        self.cond.notify_all();
        Event { done }
    }

    /// One-sided pull (`nvshmemx_getmem_on_stream`): read `src`'s window
    /// slot into a local buffer. Blocks (wall) until the slot is published;
    /// virtual-time completion respects publish time, this rank's ingress
    /// queue, and the link model. Local (src == self) reads are free.
    pub fn get(
        &self,
        clock: &mut RankClock,
        me: usize,
        src: usize,
        slot: &str,
        flows: usize,
    ) -> GetHandle {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(entry) = st.windows.get(&(src, slot.to_string())) {
                let buf = entry.buf.clone();
                let publish = entry.publish_time;
                if src == me {
                    return GetHandle { buf, done: publish.max(clock.now) };
                }
                let bytes = buf.bytes();
                let (buf, done) = if self.cluster.same_machine(src, me) {
                    let dur = self.transfer_time(src, me, bytes, flows);
                    let (_, done) = clock.reserve_ingress(publish.max(clock.now), dur);
                    st.record_transfer(src, me, bytes, false);
                    (buf, done)
                } else {
                    let earliest = publish.max(clock.now);
                    let (done, _) = self.inter_hop(
                        &mut st, clock, me, src, bytes, flows, earliest, 0.0, false,
                    );
                    (self.maybe_compress(buf), done)
                };
                drop(st);
                clock.advance(1e-6, TimeKind::Overhead);
                return GetHandle { buf, done };
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Wait for a one-sided completion event.
    pub fn wait_event(&self, clock: &mut RankClock, ev: Event) {
        clock.advance_to(ev.done, TimeKind::CommWait);
    }

    /// Wait for a pull and take the data.
    pub fn wait_get(&self, clock: &mut RankClock, h: GetHandle) -> Buf {
        clock.advance_to(h.done, TimeKind::CommWait);
        h.buf
    }

    /// Barrier over `group` (`nvshmemx_barrier_on_stream` analog): all
    /// members advance to max(arrival times) + barrier latency.
    pub fn barrier(&self, clock: &mut RankClock, group: &[usize]) {
        let mut key: Vec<usize> = group.to_vec();
        key.sort_unstable();
        let n = key.len();
        if n <= 1 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let my_gen = {
            let b = st.barriers.entry(key.clone()).or_default();
            b.arrived += 1;
            b.max_time = b.max_time.max(clock.now);
            if b.arrived == n {
                b.release_time = b.max_time + self.net().barrier_lat;
                b.generation += 1;
                b.arrived = 0;
                b.max_time = 0.0;
                let release = b.release_time;
                st.barrier_history.push(key.clone());
                self.cond.notify_all();
                drop(st);
                clock.advance_to(release, TimeKind::Sync);
                return;
            }
            b.generation
        };
        loop {
            st = self.cond.wait(st).unwrap();
            let b = st.barriers.get(&key).unwrap();
            if b.generation > my_gen {
                let release = b.release_time;
                drop(st);
                clock.advance_to(release, TimeKind::Sync);
                return;
            }
        }
    }

    /// Drop all window entries (between layers) and return current
    /// resident bytes to zero. Peak is preserved.
    pub fn clear_windows(&self) {
        let mut st = self.state.lock().unwrap();
        st.windows.clear();
        for b in st.window_bytes.iter_mut() {
            *b = 0.0;
        }
    }

    /// Peak bytes resident in a rank's windows (communication buffers) —
    /// the Fig. 7 memory-overhead metric.
    pub fn peak_window_bytes(&self, rank: usize) -> f64 {
        self.state.lock().unwrap().peak_window_bytes[rank]
    }

    /// Measured transfer volume for `rank` (see [`Traffic`]).
    pub fn traffic(&self, rank: usize) -> Traffic {
        self.state.lock().unwrap().traffic[rank]
    }

    /// Whole-run transfer volume: the per-rank [`Traffic`] counters
    /// summed (the serve report's comm section).
    pub fn traffic_totals(&self) -> Traffic {
        let st = self.state.lock().unwrap();
        st.traffic.iter().fold(Traffic::default(), |a, t| Traffic {
            intra_in: a.intra_in + t.intra_in,
            intra_out: a.intra_out + t.intra_out,
            inter_in: a.inter_in + t.inter_in,
            inter_out: a.inter_out + t.inter_out,
        })
    }

    /// Wire-seconds `rank`'s transfers occupied its NIC in scheduled
    /// mode (chunk time only, no α) — zero when
    /// [`NetSpec::nic_schedule`] is off.
    pub fn nic_busy_seconds(&self, rank: usize) -> f64 {
        self.state.lock().unwrap().nic_busy[rank]
    }

    /// Inter-machine transfers priced at the fused CFG-pair rate,
    /// summed over ranks — zero unless [`Self::set_cfg_fused`] was
    /// called with `true` before the run.
    pub fn fused_transfers(&self) -> u64 {
        self.state.lock().unwrap().fused_inter.iter().sum()
    }

    /// Aggregate comm observability of this world's run so far — one
    /// snapshot the serve engine folds into its accumulated
    /// [`CommStats`] cell after each pricing run.
    pub fn stats(&self) -> CommStats {
        let st = self.state.lock().unwrap();
        CommStats {
            traffic: st.traffic.iter().fold(Traffic::default(), |a, t| Traffic {
                intra_in: a.intra_in + t.intra_in,
                intra_out: a.intra_out + t.intra_out,
                inter_in: a.inter_in + t.inter_in,
                inter_out: a.inter_out + t.inter_out,
            }),
            nic_busy: st.nic_busy.iter().sum(),
            fused_transfers: st.fused_inter.iter().sum(),
        }
    }

    /// Every completed barrier's (sorted) rank group, in completion order —
    /// used by the Algorithm-1 sync-count tests (§4.4: intra-machine
    /// barriers plus exactly two global barriers per layer).
    pub fn barrier_history(&self) -> Vec<Vec<usize>> {
        self.state.lock().unwrap().barrier_history.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    fn world(n: usize, m: usize) -> CommWorld {
        CommWorld::new(ClusterSpec::new(n, m))
    }

    fn buf(elems: usize) -> Buf {
        Buf::Real(Tensor::zeros(&[elems]))
    }

    #[test]
    fn buf_structural_ops_match_modes() {
        let real = Buf::Real(Tensor::random(&[2, 8, 4], 3));
        let shape = Buf::Shape(vec![2, 8, 4]);
        assert_eq!(real.bytes(), shape.bytes());
        let rs = real.split(1, 4);
        let ss = shape.split(1, 4);
        assert_eq!(rs[0].shape(), ss[0].shape());
        let rc = Buf::concat(&rs, 1);
        assert_eq!(rc.shape(), &[2, 8, 4]);
        assert_eq!(rc.tensor(), real.tensor());
        let sc = Buf::concat(&ss, 1);
        assert_eq!(sc.shape(), &[2, 8, 4]);
        assert_eq!(real.slice(1, 2, 6).shape(), shape.slice(1, 2, 6).shape());
    }

    #[test]
    #[should_panic(expected = "no tensor data")]
    fn shape_buf_tensor_panics() {
        Buf::Shape(vec![2]).tensor();
    }

    #[test]
    #[should_panic(expected = "empty buffer list")]
    fn concat_empty_panics_with_reason() {
        Buf::concat(&[], 0);
    }

    #[test]
    #[should_panic(expected = "axis 3 out of bounds")]
    fn concat_axis_out_of_bounds_panics_with_reason() {
        Buf::concat(&[Buf::Shape(vec![2, 2])], 3);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn concat_mismatched_off_axis_dims_panics_with_reason() {
        Buf::concat(&[Buf::Shape(vec![2, 4]), Buf::Shape(vec![3, 4])], 1);
    }

    #[test]
    #[should_panic(expected = "zero parts")]
    fn split_zero_parts_panics_with_reason() {
        Buf::Shape(vec![8]).split(0, 0);
    }

    #[test]
    #[should_panic(expected = "unequal parts")]
    fn split_indivisible_panics_with_reason() {
        Buf::Real(Tensor::zeros(&[2, 9])).split(1, 4);
    }

    #[test]
    #[should_panic(expected = "axis 2 out of bounds")]
    fn split_axis_out_of_bounds_panics_with_reason() {
        Buf::Shape(vec![4, 4]).split(2, 2);
    }

    #[test]
    fn concat_mixed_modes_takes_shape_path() {
        // one timing-mode buf degrades the whole concat to shape-only,
        // with the axis dim summed
        let out = Buf::concat(
            &[Buf::Real(Tensor::zeros(&[1, 4])), Buf::Shape(vec![1, 2])],
            1,
        );
        assert_eq!(out.shape(), &[1, 6]);
        assert!(!out.is_real());
    }

    #[test]
    fn transfer_time_respects_topology() {
        let w = world(2, 2);
        let intra = w.transfer_time(0, 1, 1e6, 1);
        let inter = w.transfer_time(0, 2, 1e6, 1);
        assert!(inter > intra);
        // NIC fair share slows inter transfers
        assert!(w.transfer_time(0, 2, 1e6, 8) > inter);
        // but not intra ones
        assert_eq!(w.transfer_time(0, 1, 1e6, 8), intra);
    }

    #[test]
    fn two_sided_rendezvous_sets_both_clocks() {
        let w = world(1, 2);
        let mut c0 = RankClock::new();
        let mut c1 = RankClock::new();
        // receiver is late: sender must wait for it
        c1.advance(1.0, TimeKind::Compute);
        let h = w.isend(&mut c0, 0, 1, "x", buf(1024));
        let got = w.wait_recv(&mut c1, 0, 1, "x", 1);
        assert_eq!(got.shape(), &[1024]);
        w.wait_send(&mut c0, h);
        // both sides end at the same completion time >= 1.0 + sync + transfer
        assert!((c0.now - c1.now).abs() < 1e-12);
        assert!(c0.now > 1.0);
        assert_eq!(c0.two_sided_inflight, 0);
    }

    #[test]
    fn two_sided_sender_blocks_until_late_receiver() {
        let w = world(1, 2);
        let mut c0 = RankClock::new();
        let mut c1 = RankClock::new();
        c1.advance(5.0, TimeKind::Compute);
        let h = w.isend(&mut c0, 0, 1, "t", buf(16));
        let _ = w.wait_recv(&mut c1, 0, 1, "t", 1);
        w.wait_send(&mut c0, h);
        assert!(c0.now >= 5.0, "sender dragged to receiver's time (Fig 4)");
        assert!(c0.time_in(TimeKind::Sync) >= 4.9);
    }

    #[test]
    fn one_sided_put_does_not_block_sender() {
        let w = world(1, 2);
        let mut c0 = RankClock::new();
        let mut c1 = RankClock::new();
        c1.advance(5.0, TimeKind::Compute); // receiver late — sender doesn't care
        let ev = w.put(&mut c0, 0, 1, "slot", buf(1024), 1);
        assert!(c0.now < 1e-3, "put is async; sender only pays issue cost");
        let h = w.get(&mut c1, 1, 1, "slot", 1);
        let got = w.wait_get(&mut c1, h);
        assert_eq!(got.shape(), &[1024]);
        assert!(ev.done > 0.0);
    }

    #[test]
    fn get_waits_for_publish_time() {
        let w = world(1, 2);
        let mut owner = RankClock::new();
        owner.advance(2.0, TimeKind::Compute);
        w.expose(&owner, 0, "q", buf(1 << 20));
        let mut puller = RankClock::new();
        let h = w.get(&mut puller, 1, 0, "q", 1);
        let _ = w.wait_get(&mut puller, h);
        // puller can't have the data before publish(2.0) + transfer
        assert!(puller.now > 2.0);
    }

    #[test]
    fn local_get_is_free() {
        let w = world(1, 2);
        let mut c = RankClock::new();
        w.expose(&c, 0, "q", buf(1 << 20));
        let h = w.get(&mut c, 0, 0, "q", 1);
        let before = c.now;
        let _ = w.wait_get(&mut c, h);
        assert!(c.now - before < 1e-9, "local window read costs nothing");
    }

    #[test]
    fn successive_gets_serialize_on_ingress() {
        let w = world(1, 2);
        let c0 = RankClock::new();
        w.expose(&c0, 0, "a", buf(1 << 22));
        w.expose(&c0, 0, "b", buf(1 << 22));
        let mut c1 = RankClock::new();
        let ha = w.get(&mut c1, 1, 0, "a", 1);
        let hb = w.get(&mut c1, 1, 0, "b", 1);
        assert!(hb.done >= ha.done + (ha.done - 0.0) * 0.5, "second pull queues");
        let _ = w.wait_get(&mut c1, ha);
        let _ = w.wait_get(&mut c1, hb);
    }

    #[test]
    fn barrier_aligns_group_to_max() {
        let w = world(1, 3);
        let clocks: Vec<_> = (0..3)
            .map(|i| {
                let mut c = RankClock::new();
                c.advance(i as f64, TimeKind::Compute);
                c
            })
            .collect();
        let out = crate::util::pool::scoped_run(
            clocks
                .into_iter()
                .enumerate()
                .map(|(i, mut c)| {
                    let w = &w;
                    move || {
                        w.barrier(&mut c, &[0, 1, 2]);
                        (i, c.now)
                    }
                })
                .collect::<Vec<_>>(),
        );
        let expect = 2.0 + w.net().barrier_lat;
        for (i, now) in out {
            assert!((now - expect).abs() < 1e-9, "rank {i}: {now} != {expect}");
        }
    }

    #[test]
    fn barrier_is_reusable() {
        let w = world(1, 2);
        for round in 0..3 {
            let out = crate::util::pool::scoped_run(
                (0..2)
                    .map(|i| {
                        let w = &w;
                        move || {
                            let mut c = RankClock::new();
                            c.advance(round as f64 + i as f64, TimeKind::Compute);
                            w.barrier(&mut c, &[0, 1]);
                            c.now
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            assert!((out[0] - out[1]).abs() < 1e-12, "round {round}");
        }
    }

    #[test]
    fn window_memory_accounting() {
        let w = world(1, 2);
        let c = RankClock::new();
        w.expose(&c, 0, "a", buf(256)); // 1024 bytes
        w.expose(&c, 0, "b", buf(256));
        assert_eq!(w.peak_window_bytes(0), 2048.0);
        w.clear_windows();
        assert_eq!(w.peak_window_bytes(0), 2048.0, "peak survives clear");
        let c2 = RankClock::new();
        w.expose(&c2, 0, "c", buf(64));
        assert_eq!(w.peak_window_bytes(0), 2048.0);
    }

    #[test]
    fn transfer_time_alpha_beta_hand_computed() {
        // The α–β arithmetic pinned against the p4de preset by hand:
        // intra = 3 µs + B/300 GB/s, inter = 15 µs + B·flows/25 GB/s.
        let w = world(2, 2);
        let b = 1e6;
        assert_eq!(w.transfer_time(0, 1, b, 1), 3e-6 + b / 300e9);
        assert_eq!(w.transfer_time(0, 2, b, 1), 15e-6 + b / 25e9);
        // NIC fair share: 4 concurrent flows quarter the bandwidth —
        // the +120 µs at 1 MB is the contention the scheduler removes
        let shared = w.transfer_time(0, 2, b, 4);
        assert_eq!(shared, 15e-6 + b / (25e9 / 4.0));
        assert!((shared - (15e-6 + 4.0 * 40e-6)).abs() < 1e-12);
        // intra transfers never pay the NIC share
        assert_eq!(w.transfer_time(0, 1, b, 4), 3e-6 + b / 300e9);
    }

    #[test]
    fn scheduled_nic_staggers_and_amortizes_alpha() {
        // TDMA chunk scheduling, hand-computed: chunk time c = B/25 GB/s
        // at FULL bandwidth; a fresh burst staggers by the rank's slot,
        // queued chunks wait one lane period (flows·c) but never re-pay α.
        let mut cluster = ClusterSpec::new(2, 2);
        cluster.net.nic_schedule = true;
        let w = CommWorld::new(cluster);
        let c0 = RankClock::new();
        w.expose(&c0, 0, "a", buf(1 << 20));
        w.expose(&c0, 0, "b", buf(1 << 20));
        let bytes = (1u64 << 22) as f64; // 2^20 elems × 4 B
        let c = bytes / 25e9;
        let alpha = 15e-6;
        // rank 2: local index 0 → slot 0 of 2: first chunk unstaggered
        let mut puller = RankClock::new();
        let ha = w.get(&mut puller, 2, 0, "a", 2);
        let hb = w.get(&mut puller, 2, 0, "b", 2);
        assert!((ha.done - (alpha + c)).abs() < 1e-12, "{}", ha.done);
        // second pull queues on the lane (free at 2c), not on α
        assert!((hb.done - (2.0 * c + alpha + c)).abs() < 1e-12, "{}", hb.done);
        assert!((w.nic_busy_seconds(2) - 2.0 * c).abs() < 1e-15);
        // rank 3: local index 1 → slot 1 of 2: staggered one chunk
        let mut p3 = RankClock::new();
        let h3 = w.get(&mut p3, 3, 0, "a", 2);
        assert!((h3.done - (c + alpha + c)).abs() < 1e-12, "{}", h3.done);
        // completions beat the constant fair-share model (duration
        // α + flows·c, serialized on the ingress queue): strictly for
        // early slots and queued chunks; the last slot's first chunk
        // lands exactly at the constant-model time (slot (f−1)·c + c =
        // f·c), which is why aggregate NIC throughput is conserved
        let const_dur = alpha + 2.0 * c;
        assert!(ha.done < const_dur);
        assert!(hb.done < 2.0 * const_dur);
        assert!((h3.done - const_dur).abs() < 1e-12);
        // intra pulls don't touch the NIC lane
        let mut p1 = RankClock::new();
        let h1 = w.get(&mut p1, 1, 0, "a", 2);
        assert_eq!(h1.done, 3e-6 + bytes / 300e9);
        assert_eq!(w.nic_busy_seconds(1), 0.0);
    }

    #[test]
    fn compressed_inter_hop_halves_wire_bytes_and_quantizes() {
        let mut cluster = ClusterSpec::new(2, 2);
        cluster.net.inter_compress = 0.5;
        let w = CommWorld::new(cluster);
        let t = Tensor::random(&[1024], 7);
        let bytes = 4096.0;
        let mut c0 = RankClock::new();
        let ev = w.put(&mut c0, 0, 2, "x", Buf::Real(t.clone()), 1);
        // the timing model and the Traffic counters both see wire bytes
        assert!((ev.done - (15e-6 + bytes * 0.5 / 25e9)).abs() < 1e-15);
        assert_eq!(w.traffic(0).inter_out, bytes * 0.5);
        assert_eq!(w.traffic(2).inter_in, bytes * 0.5);
        // the payload is quantized to the 16-bit symmetric grid: error
        // per element ≤ amax/(2·(2^15−1))
        let mut c2 = RankClock::new();
        let got = w.wait_get(&mut c2, w.get(&mut c2, 2, 2, "x", 1));
        let amax = t.data().iter().fold(0f32, |a, v| a.max(v.abs()));
        let bound = amax / 32767.0;
        let err = t
            .data()
            .iter()
            .zip(got.tensor().data())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(err <= bound, "quantization error {err} vs bound {bound}");
        assert!(err > 0.0, "compression must actually quantize");
        // intra hops ship full precision and full bytes
        let mut c1 = RankClock::new();
        let local = w.put(&mut c1, 0, 1, "y", Buf::Real(t.clone()), 1);
        assert_eq!(w.traffic(1).intra_in, bytes);
        assert!(local.done > 0.0);
        let mut cr = RankClock::new();
        let intact = w.wait_get(&mut cr, w.get(&mut cr, 1, 1, "y", 1));
        assert_eq!(intact.tensor(), &t);
    }

    #[test]
    fn fused_world_halves_alpha_and_rendezvous() {
        let fused = world(2, 2);
        fused.set_cfg_fused(true);
        let plain = world(2, 2);
        let c0 = RankClock::new();
        fused.expose(&c0, 0, "q", buf(1 << 20));
        plain.expose(&c0, 0, "q", buf(1 << 20));
        let bytes = (1u64 << 22) as f64;
        let mut pf = RankClock::new();
        let hf = fused.get(&mut pf, 2, 0, "q", 1);
        let mut pp = RankClock::new();
        let hp = plain.get(&mut pp, 2, 0, "q", 1);
        // one-sided: the fused flow pays half the per-transfer α
        assert!((hf.done - (7.5e-6 + bytes / 25e9)).abs() < 1e-15);
        assert!((hp.done - (15e-6 + bytes / 25e9)).abs() < 1e-15);
        assert_eq!(fused.fused_transfers(), 1);
        assert_eq!(plain.fused_transfers(), 0);
        // two-sided: the rendezvous sync halves too
        let mut s = RankClock::new();
        let mut r = RankClock::new();
        let h = fused.isend(&mut s, 0, 2, "m", buf(256));
        let got = fused.wait_recv(&mut r, 0, 2, "m", 1);
        fused.wait_send(&mut s, h);
        assert_eq!(got.shape(), &[256]);
        let dur = (7.5e-6 + 1024.0 / 25e9) * 1.12;
        // sender_ready = 0, receiver posts at 0: earliest = 0 + sync/2
        assert!((s.now - (5e-6 + dur)).abs() < 1e-12, "{}", s.now);
        assert_eq!(fused.fused_transfers(), 2);
    }

    #[test]
    fn cross_thread_send_recv_delivers_data() {
        let w = world(1, 2);
        let payload = Tensor::random(&[32], 5);
        let p2 = payload.clone();
        let out = crate::util::pool::scoped_run(vec![
            Box::new({
                let w = &w;
                let payload = payload.clone();
                move || {
                    let mut c = RankClock::new();
                    let h = w.isend(&mut c, 0, 1, "d", Buf::Real(payload));
                    w.wait_send(&mut c, h);
                    None
                }
            }) as Box<dyn FnOnce() -> Option<Tensor> + Send>,
            Box::new({
                let w = &w;
                move || {
                    let mut c = RankClock::new();
                    Some(w.wait_recv(&mut c, 0, 1, "d", 1).into_tensor())
                }
            }),
        ]);
        assert_eq!(out[1].as_ref().unwrap(), &p2);
    }
}
