//! Integration regressions for epoch-aware serving (dynamic re-carving):
//!
//! * `RecarvePolicy::Never` must reproduce the pre-epoch (static-plan)
//!   serving results **bit-for-bit** — the epoch machinery may not
//!   perturb a pod whose plan never changes;
//! * the serving report's plan histogram and the new epoch/drain fields
//!   must serialize stably (JSON golden);
//! * epoch accounting must be exact under a hand-checkable scripted
//!   service model.

use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::config::{ClusterSpec, ParallelSpec, SpDegrees};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{serve, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::{CostModel, Planner};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{Request, TraceGen, Workload};

/// Fixed-plan serving under the default (`Free`) policy vs an explicit
/// `Never` policy: with a static plan the preferred spec never changes,
/// so freezing the admission carve must be *exactly* the pre-epoch
/// behaviour — identical completions, horizon, histogram, rejections.
#[test]
fn never_policy_matches_static_plan_serving_bit_for_bit() {
    let cluster = ClusterSpec::new(4, 8);
    let spec = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
    let algo = SpAlgo::SwiftFusion;
    let run = |policy: Option<RecarvePolicy>| -> ServeReport {
        let svc = SimService::with_plan(cluster.clone(), algo, spec).unwrap();
        let mut router = Router::new(4, 8, 1, algo);
        if let Some(p) = policy {
            router.set_recarve(p);
        }
        let reqs = TraceGen::new(42, 0.05, Workload::paper_suite()).take(24);
        serve(&mut router, BatchPolicy { max_batch: 2, window: 10.0 }, reqs, &svc)
    };
    let legacy = run(None); // default Free = pre-epoch behaviour
    let frozen = run(Some(RecarvePolicy::Never));

    assert_eq!(legacy.completions, frozen.completions, "bit-for-bit completions");
    assert_eq!(legacy.metrics.horizon.to_bits(), frozen.metrics.horizon.to_bits());
    assert_eq!(legacy.metrics.completed(), frozen.metrics.completed());
    assert_eq!(legacy.plan_histogram, frozen.plan_histogram);
    assert_eq!(legacy.rejected, frozen.rejected);
    // and neither run paid a single transition
    assert_eq!(legacy.recarve.recarve_count, 0);
    assert_eq!(frozen.recarve.recarve_count, 0);
    assert_eq!(frozen.recarve.epochs.len(), 1, "one frozen epoch");
    assert_eq!(
        frozen.recarve.epochs[0].1.served,
        frozen.metrics.completed(),
        "every request served inside the admission epoch"
    );
}

/// A scripted service model with hand-computable times: preferred-plan
/// dispatches cost 0.5 s, stale ones 2 s, and every cross-plan gain
/// prediction is 0.75.
struct StubService;

impl StubService {
    fn spec_for(w: &Workload) -> ParallelSpec {
        if w.name.starts_with("flux") {
            ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
        } else {
            ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
        }
    }
}

impl CostModel for StubService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        0.5 * batch as f64
    }

    fn service_time_under(
        &self,
        w: &Workload,
        batch: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        if carve.copied() == Some(Self::spec_for(w)) {
            0.5 * batch as f64
        } else {
            2.0 * batch as f64
        }
    }
}

impl Planner for StubService {
    fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
        Some(Self::spec_for(w))
    }

    fn plan_label(&self, w: &Workload) -> Option<String> {
        Some(Self::spec_for(w).label())
    }

    fn recarve_gain(&self, _w: &Workload, _from: &ParallelSpec) -> Option<f64> {
        Some(0.75)
    }
}

fn scripted_trace() -> Vec<Request> {
    let mk = |id: u64, w: Workload, arrival: f64| Request { id, workload: w, arrival, seed: id };
    vec![
        mk(0, Workload::flux_3072(), 0.0),
        mk(1, Workload::flux_3072(), 1.0),
        mk(2, Workload::cogvideo_20s(), 2.0),
        mk(3, Workload::cogvideo_20s(), 3.0),
        mk(4, Workload::cogvideo_20s(), 4.0),
        mk(5, Workload::flux_3072(), 5.0),
    ]
}

fn scripted_report() -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    router.set_recarve_with_setup(
        RecarvePolicy::Hysteresis { threshold: 0.5, window: 2 },
        0.25,
    );
    serve(
        &mut router,
        BatchPolicy { max_batch: 1, window: 0.0 },
        scripted_trace(),
        &StubService,
    )
}

/// Hand-checked epoch arithmetic for the scripted trace: the pod adopts
/// the flux plan, holds it for one gainful video dispatch (hysteresis
/// window 2), then drains 1 s, pays 0.25 s of re-setup, and opens the
/// video epoch at t = 4.25.
#[test]
fn scripted_hysteresis_run_has_exact_epoch_accounting() {
    let report = scripted_report();
    assert_eq!(report.metrics.completed(), 6);
    assert_eq!(report.metrics.horizon, 7.25);
    assert_eq!(report.recarve.recarve_count, 1);
    assert_eq!(report.recarve.drain_time, 1.0);
    assert_eq!(report.recarve.setup_time, 0.25);
    let epochs = &report.recarve.epochs;
    assert_eq!(epochs.len(), 2);
    assert_eq!(epochs[0].1.started_at, 0.0);
    assert_eq!(epochs[0].1.served, 3, "flux x2 + one stale video");
    assert_eq!(epochs[1].1.started_at, 4.25, "drain to 4.0 + 0.25 setup");
    assert_eq!(epochs[1].1.served, 3, "video x2 + one stale flux");
    // per-carve histogram: three requests served under each plan
    assert_eq!(
        report.plan_histogram.get("cfg1 x pp1 x rep4 x U8R1"),
        Some(&3)
    );
    assert_eq!(
        report.plan_histogram.get("cfg2 x pp2 x rep1 x U8R1"),
        Some(&3)
    );
}

/// Golden serialization: `ServeReport::to_json` (plan histogram + the
/// epoch/drain fields added with dynamic re-carving) must render this
/// exact string. If a field is added, renamed, or re-ordered, update the
/// golden deliberately — downstream tooling parses this.
#[test]
fn serve_report_json_is_stable() {
    let report = scripted_report();
    let golden = concat!(
        "{\"completed\":6,\"horizon\":7.25,",
        "\"plan_histogram\":{",
        "\"cfg1 x pp1 x rep4 x U8R1\":3,",
        "\"cfg2 x pp2 x rep1 x U8R1\":3},",
        "\"recarve\":{\"count\":1,\"drain_time\":1,",
        "\"epoch_histogram\":{",
        "\"cfg1 x pp1 x rep4 x U8R1\":1,",
        "\"cfg2 x pp2 x rep1 x U8R1\":1},",
        "\"epochs\":[",
        "{\"index\":0,\"plan\":\"cfg1 x pp1 x rep4 x U8R1\",\"pod\":0,",
        "\"served\":3,\"started_at\":0},",
        "{\"index\":1,\"plan\":\"cfg2 x pp2 x rep1 x U8R1\",\"pod\":0,",
        "\"served\":3,\"started_at\":4.25}],",
        "\"setup_time\":0.25},",
        "\"rejected\":[]}",
    );
    assert_eq!(to_string(&report.to_json()), golden);
}
