//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts`. Verifies the full python→HLO-text→rust
//! bridge: artifact loading, compilation, execution, shape checking, and
//! the numeric semantics of the attention tile kernels (partial / merge /
//! finalize compose to exact softmax attention).

use swiftfusion::runtime::Runtime;
use swiftfusion::tensor::Tensor;

/// Skip (not fail) when PJRT or the artifacts are unavailable.
macro_rules! runtime_or_skip {
    () => {
        match Runtime::load_default_if_available() {
            Some(rt) => rt,
            None => return,
        }
    };
}

/// Software oracle: plain f32 softmax attention on the host, the same
/// math as python's kernels.ref (independent reimplementation).
fn host_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (b, lq, h, d) = (q.shape()[0], q.shape()[1], q.shape()[2], q.shape()[3]);
    let lk = k.shape()[1];
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; b * lq * h * d];
    let at = |t: &Tensor, bi: usize, li: usize, hi: usize, di: usize| {
        t.data()[((bi * t.shape()[1] + li) * h + hi) * d + di]
    };
    for bi in 0..b {
        for hi in 0..h {
            for qi in 0..lq {
                let mut scores = vec![0f32; lk];
                for ki in 0..lk {
                    let mut s = 0f32;
                    for di in 0..d {
                        s += at(q, bi, qi, hi, di) * at(k, bi, ki, hi, di);
                    }
                    scores[ki] = s * scale;
                }
                let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0f32;
                for s in scores.iter_mut() {
                    *s = (*s - m).exp();
                    z += *s;
                }
                for di in 0..d {
                    let mut acc = 0f32;
                    for ki in 0..lk {
                        acc += scores[ki] * at(v, bi, ki, hi, di);
                    }
                    out[((bi * lq + qi) * h + hi) * d + di] = acc / z;
                }
            }
        }
    }
    Tensor::new(vec![b, lq, h, d], out).unwrap()
}

#[test]
fn manifest_has_expected_configs() {
    let rt = runtime_or_skip!();
    let m = rt.manifest();
    assert!(m.config("small4").is_ok());
    assert!(m.config("small8").is_ok());
    let c4 = m.config("small4").unwrap();
    assert_eq!((c4.b, c4.l, c4.h, c4.d), (1, 128, 4, 16));
    assert_eq!(c4.chunk * c4.mesh, c4.l);
}

#[test]
fn attn_full_matches_host_oracle() {
    let rt = runtime_or_skip!();
    let c = rt.manifest().config("small4").unwrap().clone();
    let q = Tensor::random(&[c.b, c.l, c.h, c.d], 11);
    let k = Tensor::random(&[c.b, c.l, c.h, c.d], 12);
    let v = Tensor::random(&[c.b, c.l, c.h, c.d], 13);
    let got = rt
        .handle()
        .call("attn_full_small4", &[q.clone(), k.clone(), v.clone()])
        .unwrap();
    let want = host_attention(&q, &k, &v);
    let diff = got[0].max_abs_diff(&want);
    assert!(diff < 1e-4, "pallas kernel vs host oracle: {diff}");
}

#[test]
fn partial_chain_plus_finalize_equals_full() {
    // The tile contract every SP algorithm relies on: absorbing KV chunks
    // via the carry kernel then finalizing == full attention.
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let c = rt.manifest().config("small4").unwrap().clone();
    let (b, lc, hh, d) = (c.b, c.chunk, c.h, c.d);
    let lk = c.l;

    let q = Tensor::random(&[b, lc, hh, d], 21);
    let k = Tensor::random(&[b, lk, hh, d], 22);
    let v = Tensor::random(&[b, lk, hh, d], 23);

    let mut o = Tensor::zeros(&[b, lc, hh, d]);
    let mut l = Tensor::zeros(&[b, hh, lc]);
    let mut m = Tensor::neg_inf(&[b, hh, lc]);
    for i in 0..(lk / lc) {
        let ks = k.slice(1, i * lc, (i + 1) * lc).unwrap();
        let vs = v.slice(1, i * lc, (i + 1) * lc).unwrap();
        let out = h
            .call(
                &format!("attn_partial_small4_h{hh}"),
                &[q.clone(), ks, vs, o, l, m],
            )
            .unwrap();
        let mut it = out.into_iter();
        o = it.next().unwrap();
        l = it.next().unwrap();
        m = it.next().unwrap();
    }
    let fin = h
        .call(&format!("attn_finalize_small4_h{hh}"), &[o, l])
        .unwrap();
    let want = host_attention(&q, &k, &v);
    let diff = fin[0].max_abs_diff(&want);
    assert!(diff < 1e-4, "partial chain vs oracle: {diff}");
}

#[test]
fn merge_is_order_insensitive() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let c = rt.manifest().config("small4").unwrap().clone();
    let (b, lc, g, d) = (c.b, c.chunk, 2usize, c.d);

    let q = Tensor::random(&[b, lc, g, d], 31);
    let mk = |seed| {
        (
            Tensor::random(&[b, lc, g, d], seed),
            Tensor::random(&[b, lc, g, d], seed + 1),
        )
    };
    let (k1, v1) = mk(32);
    let (k2, v2) = mk(40);

    let partial = |k: &Tensor, v: &Tensor| {
        let out = h
            .call(
                &format!("attn_partial_small4_h{g}"),
                &[
                    q.clone(),
                    k.clone(),
                    v.clone(),
                    Tensor::zeros(&[b, lc, g, d]),
                    Tensor::zeros(&[b, g, lc]),
                    Tensor::neg_inf(&[b, g, lc]),
                ],
            )
            .unwrap();
        (out[0].clone(), out[1].clone(), out[2].clone())
    };
    let a = partial(&k1, &v1);
    let bb = partial(&k2, &v2);
    let merge = |x: &(Tensor, Tensor, Tensor), y: &(Tensor, Tensor, Tensor)| {
        h.call(
            &format!("attn_merge_small4_h{g}"),
            &[
                x.0.clone(),
                x.1.clone(),
                x.2.clone(),
                y.0.clone(),
                y.1.clone(),
                y.2.clone(),
            ],
        )
        .unwrap()
    };
    let ab = merge(&a, &bb);
    let ba = merge(&bb, &a);
    for (x, y) in ab.iter().zip(&ba) {
        assert!(x.max_abs_diff(y) < 1e-5, "merge must commute");
    }
}

#[test]
fn dit_forward_is_deterministic_and_finite() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let c = rt.manifest().config("small4").unwrap().clone();
    let x = Tensor::random(&[c.b, c.l, c.c_in], 55);
    let t = Tensor::new(vec![c.b], vec![500.0; c.b]).unwrap();
    let e1 = h.call("dit_forward_small4", &[x.clone(), t.clone()]).unwrap();
    let e2 = h.call("dit_forward_small4", &[x, t]).unwrap();
    assert!(e1[0].is_finite());
    assert_eq!(e1[0], e2[0], "same inputs, same outputs");
    assert_eq!(e1[0].shape(), &[c.b, c.l, c.c_in]);
}

#[test]
fn ddim_step_preserves_shape_and_identity() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let c = rt.manifest().config("small4").unwrap().clone();
    let x = Tensor::random(&[c.b, c.l, c.c_in], 60);
    let eps = Tensor::random(&[c.b, c.l, c.c_in], 61);
    // abar_t == abar_prev => x unchanged
    let out = h
        .call(
            "ddim_step_small4",
            &[x.clone(), eps, Tensor::scalar(0.5), Tensor::scalar(0.5)],
        )
        .unwrap();
    assert!(out[0].max_abs_diff(&x) < 1e-5);
}

#[test]
fn vae_decode_in_unit_range() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let c = rt.manifest().config("small4").unwrap().clone();
    let x = Tensor::random(&[c.b, c.l, c.c_in], 70);
    let img = h.call("vae_decode_small4", &[x]).unwrap();
    assert_eq!(img[0].shape(), &[c.b, c.l, 12]);
    assert!(img[0].data().iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn shape_mismatch_is_rejected_before_xla() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    let bad = Tensor::zeros(&[1, 64, 4, 16]); // wrong L
    let err = h
        .call("attn_full_small4", &[bad.clone(), bad.clone(), bad])
        .unwrap_err();
    assert!(err.to_string().contains("shape"));
}

#[test]
fn precompile_then_call_works() {
    let rt = runtime_or_skip!();
    let h = rt.handle();
    h.precompile(&["attn_full_small4"]).unwrap();
    let c = rt.manifest().config("small4").unwrap().clone();
    let q = Tensor::random(&[c.b, c.l, c.h, c.d], 80);
    let out = h
        .call("attn_full_small4", &[q.clone(), q.clone(), q])
        .unwrap();
    assert!(out[0].is_finite());
    assert!(rt.stats().calls.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}
