//! Integration regressions for the `ServeSession` scheduler redesign:
//!
//! * the legacy `serve()` entry point is a thin shim over `ServeSession`
//!   and must reproduce it **bit-for-bit** on the bimodal re-carving
//!   trace (golden `ServeReport::to_json` parity);
//! * replica co-batching: replica groups serve one shared batch —
//!   throughput up, per-request latency bounded (exact arithmetic under
//!   a scripted model, and a real `SimService` burst);
//! * cross-pod re-balancing: on a drifting pod-mix trace, migrating an
//!   idle machine toward the video pod beats the frozen 2+2 fleet;
//! * the batcher flush-deadline edge at the serving-loop level.

use std::sync::Arc;

use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::analysis::DISPLACED_TIME_FACTOR;
use swiftfusion::config::{ParallelSpec, QualityMode, SpDegrees};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{serve, PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    EarliestFinish, RebalancePolicy, ServeConfig, ServeSession, SimFleet,
};
use swiftfusion::coordinator::{CostModel, Planner};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{bimodal_trace, phased_trace, Request, Workload};

/// The recarve-bench workload pair, shrunk (2 layers × 2 steps) so the
/// timing simulations stay fast — same shapes the engine unit tests use.
fn short_workload() -> Workload {
    let mut w = Workload::short_image_4k();
    w.layers = 2;
    w.steps = 2;
    w
}

fn long_workload() -> Workload {
    let mut w = Workload::cfg_video_96k();
    w.layers = 2;
    w.steps = 2;
    w
}

// ---------------------------------------------------------------------------
// Golden parity: legacy serve() shim vs ServeSession
// ---------------------------------------------------------------------------

/// Legacy entry (router setters + `serve()`) vs the new API
/// (`ServeConfig` + `ServeSession`) on the bimodal re-carving trace:
/// identical completions, bit-identical horizon, and byte-identical
/// `to_json` — the redesign may not perturb a single result.
#[test]
fn serve_session_matches_legacy_serve_bit_for_bit() {
    let trace = || bimodal_trace(&short_workload(), &long_workload(), 3, 6);
    let policy = RecarvePolicy::Hysteresis { threshold: 0.05, window: 2 };
    let batch = BatchPolicy { max_batch: 1, window: 0.0 };

    let legacy: ServeReport = {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(policy, 0.01);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        serve(&mut router, batch.clone(), trace(), &svc)
    };
    let session: ServeReport = {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(batch.clone())
            .plan(PlanPolicy::Auto)
            .recarve(policy)
            .recarve_setup(0.01);
        ServeSession::new(config, &svc).run(&mut router, trace())
    };

    assert_eq!(legacy.completions, session.completions, "bit-for-bit completions");
    assert_eq!(
        legacy.metrics.horizon.to_bits(),
        session.metrics.horizon.to_bits(),
        "bit-for-bit horizon"
    );
    assert_eq!(legacy.rejected, session.rejected);
    assert_eq!(legacy.plan_histogram, session.plan_histogram);
    assert_eq!(legacy.recarve.recarve_count, session.recarve.recarve_count);
    assert_eq!(
        to_string(&legacy.to_json()),
        to_string(&session.to_json()),
        "byte-identical serialized reports"
    );
    // the adaptive run actually exercised the epoch machinery
    assert!(legacy.recarve.recarve_count >= 1);
    // and neither new capability leaked into a default-config run
    assert!(legacy.rebalances.is_empty() && session.rebalances.is_empty());
    assert_eq!((legacy.co_batched, session.co_batched), (0, 0));
    assert!(!to_string(&session.to_json()).contains("rebalance\":["));
    assert!(!to_string(&session.to_json()).contains("co_batched"));
}

/// The one deliberate observable change of the shim: completions are
/// recorded in completion-time order. On multiple pods a later dispatch
/// can finish first — the report must order by completion, not
/// dispatch, and still account every request exactly once.
#[test]
fn multi_pod_completions_are_in_completion_time_order() {
    struct PerWorkload;
    impl CostModel for PerWorkload {
        fn service_time(&self, w: &Workload, _b: usize) -> f64 {
            // videos take far longer than images
            if w.name.starts_with("cfg-video") { 10.0 } else { 1.0 }
        }
    }
    impl Planner for PerWorkload {}
    // video dispatched first (pod 0), image right after (pod 1): the
    // image completes first and must lead the completions vec
    let reqs = vec![
        Request { id: 0, workload: long_workload(), arrival: 0.0, seed: 0 },
        Request { id: 1, workload: short_workload(), arrival: 0.1, seed: 1 },
    ];
    let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 1, window: 0.0 },
        reqs,
        &PerWorkload,
    );
    assert_eq!(report.metrics.completed(), 2);
    let ids: Vec<u64> = report.completions.iter().map(|c| c.0).collect();
    assert_eq!(ids, vec![1, 0], "image (done 1.1) precedes video (done 10.0)");
    let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
    assert!(dones.windows(2).all(|w| w[0] <= w[1]));
}

// ---------------------------------------------------------------------------
// Group-granular (partial) re-carving
// ---------------------------------------------------------------------------

/// The recarve_serving.rs scripted model, duplicated here so the golden
/// below is hermetic: preferred-plan dispatches cost 0.5 s, stale ones
/// 2 s, every cross-plan gain prediction is 0.75, and no subset planning
/// is offered (plan_spec_on stays at its `None` default).
struct StubService;

impl StubService {
    fn spec_for(w: &Workload) -> ParallelSpec {
        if w.name.starts_with("flux") {
            ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
        } else {
            ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
        }
    }
}

impl CostModel for StubService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        0.5 * batch as f64
    }

    fn service_time_under(
        &self,
        w: &Workload,
        batch: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        if carve.copied() == Some(Self::spec_for(w)) {
            0.5 * batch as f64
        } else {
            2.0 * batch as f64
        }
    }
}

impl Planner for StubService {
    fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
        Some(Self::spec_for(w))
    }

    fn plan_label(&self, w: &Workload) -> Option<String> {
        Some(Self::spec_for(w).label())
    }

    fn recarve_gain(&self, _w: &Workload, _from: &ParallelSpec) -> Option<f64> {
        Some(0.75)
    }
}

fn scripted_trace() -> Vec<Request> {
    let mk = |id: u64, w: Workload, arrival: f64| Request { id, workload: w, arrival, seed: id };
    vec![
        mk(0, Workload::flux_3072(), 0.0),
        mk(1, Workload::flux_3072(), 1.0),
        mk(2, Workload::cogvideo_20s(), 2.0),
        mk(3, Workload::cogvideo_20s(), 3.0),
        mk(4, Workload::cogvideo_20s(), 4.0),
        mk(5, Workload::flux_3072(), 5.0),
    ]
}

/// Golden: with partial re-carving **off** (`--recarve hysteresis`), the
/// scripted hysteresis run through `ServeSession` renders the exact
/// byte string the PR-3 golden pinned — the group-granular machinery in
/// the tree perturbs nothing unless the `partial` policy is selected,
/// and none of its fields (`partial`, `co_batched_cross`, group epochs)
/// leak into the serialized report.
#[test]
fn hysteresis_golden_is_bit_for_bit_unchanged_when_partial_is_off() {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .recarve(RecarvePolicy::Hysteresis { threshold: 0.5, window: 2 })
        .recarve_setup(0.25);
    let report = ServeSession::new(config, &StubService).run(&mut router, scripted_trace());
    let golden = concat!(
        "{\"completed\":6,\"horizon\":7.25,",
        "\"plan_histogram\":{",
        "\"cfg1 x pp1 x rep4 x U8R1\":3,",
        "\"cfg2 x pp2 x rep1 x U8R1\":3},",
        "\"recarve\":{\"count\":1,\"drain_time\":1,",
        "\"epoch_histogram\":{",
        "\"cfg1 x pp1 x rep4 x U8R1\":1,",
        "\"cfg2 x pp2 x rep1 x U8R1\":1},",
        "\"epochs\":[",
        "{\"index\":0,\"plan\":\"cfg1 x pp1 x rep4 x U8R1\",\"pod\":0,",
        "\"served\":3,\"started_at\":0},",
        "{\"index\":1,\"plan\":\"cfg2 x pp2 x rep1 x U8R1\",\"pod\":0,",
        "\"served\":3,\"started_at\":4.25}],",
        "\"setup_time\":0.25},",
        "\"rejected\":[]}",
    );
    assert_eq!(to_string(&report.to_json()), golden);
    assert_eq!(report.recarve.partial_splits, 0);
    assert_eq!(report.co_batched_cross, 0);
}

/// The *partial* policy on the same scripted trace: without a subset
/// planner (`StubService` keeps the `plan_spec_on` default of `None`)
/// the split falls back to exactly the pod-wide hysteresis transition —
/// graceful degradation, byte for byte.
#[test]
fn partial_without_a_subset_planner_degrades_to_hysteresis_bit_for_bit() {
    let run = |policy: RecarvePolicy| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .recarve(policy)
            .recarve_setup(0.25);
        ServeSession::new(config, &StubService).run(&mut router, scripted_trace())
    };
    let hysteresis = run(RecarvePolicy::Hysteresis { threshold: 0.5, window: 2 });
    let partial = run(RecarvePolicy::Partial { threshold: 0.5, window: 2 });
    assert_eq!(
        to_string(&hysteresis.to_json()),
        to_string(&partial.to_json()),
        "no subset planner => partial must degrade to pod-wide hysteresis"
    );
    assert_eq!(partial.recarve.partial_splits, 0);
}

/// Partial re-carving through the real timing model: on the saturated
/// bimodal trace the video phase hits a busy pod, the auto planner
/// carves the 3 idle machines for the videos, and the pod runs two
/// generations — every request is served exactly once and attributed to
/// exactly one (pod-wide or group) epoch, with zero drain paid.
#[test]
fn partial_recarving_splits_the_simulated_pod_and_accounts_every_request() {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .recarve(RecarvePolicy::Partial { threshold: 0.05, window: 2 })
        .recarve_setup(0.01);
    let trace = bimodal_trace(&short_workload(), &long_workload(), 3, 6);
    let n = trace.len();
    let report = ServeSession::new(config, &svc).run(&mut router, trace);
    assert_eq!(report.metrics.completed(), n);
    assert!(report.rejected.is_empty());
    assert!(
        report.recarve.partial_splits >= 1,
        "the video phase must split the busy pod: {:?}",
        report.recarve.group_epochs
    );
    assert_eq!(report.recarve.drain_time, 0.0, "splits never drain");
    // every request lands in exactly one generation's epoch log
    let main_served: usize = report.recarve.epochs.iter().map(|(_, e)| e.served).sum();
    let side_served: usize =
        report.recarve.group_epochs.iter().map(|(_, g)| g.served).sum();
    assert_eq!(main_served + side_served, n);
    assert!(side_served >= 1, "the side generation served the shifted traffic");
    // the side generation is a whole-machine subset of the 4-machine pod
    for (_, g) in &report.recarve.group_epochs {
        assert!(g.machines >= 1 && g.base_machine + g.machines <= 4);
        let spec = g.plan.expect("auto planner always provides a subset plan");
        assert_eq!(spec.total_ranks(), g.machines * 8, "spec tiles its subset");
    }
    // observability: the partial block serializes and round-trips
    let json = to_string(&report.to_json());
    assert!(json.contains("\"partial\":{"), "{json}");
    assert!(swiftfusion::util::json::Json::parse(&json).is_ok());
}

// ---------------------------------------------------------------------------
// Arrival-mix forecasting: proactive re-carving + cost-gated absorb
// ---------------------------------------------------------------------------

/// The predictive-planning claim, in exact scripted arithmetic: on a
/// phased flux → video trace, hysteresis serves one stale 2 s video
/// while it waits out its confirmation window, then pays a 1 s drain
/// because the confirming dispatch lands on a busy pod. The forecast
/// policy runs the *same* gain arithmetic, but the EWMA already sees
/// the video phase at its first arrival and short-circuits the window:
/// the re-carve lands at the front of the phase shift, on a still-idle
/// pod (zero drain), and the run finishes strictly sooner.
#[test]
fn forecast_recarving_beats_hysteresis_on_the_phased_trace() {
    let trace = || phased_trace(&[(&Workload::flux_3072(), 4), (&Workload::cogvideo_20s(), 4)]);
    let run = |policy: RecarvePolicy, window: Option<f64>| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let mut config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .recarve(policy)
            .recarve_setup(0.25);
        if let Some(w) = window {
            config = config.forecast_window(w);
        }
        ServeSession::new(config, &StubService).run(&mut router, trace())
    };
    let hysteresis = run(RecarvePolicy::Hysteresis { threshold: 0.5, window: 2 }, None);
    let forecast = run(RecarvePolicy::Forecast { threshold: 0.5, window: 2 }, Some(1.0));

    assert_eq!(hysteresis.metrics.completed(), 8);
    assert_eq!(forecast.metrics.completed(), 8);
    // hysteresis: stale 2 s video at t=4, streak confirms at t=5 on the
    // now-busy pod (1 s drain + 0.25 s setup), then 0.5 s videos
    assert_eq!(hysteresis.metrics.horizon, 7.75);
    assert_eq!(hysteresis.recarve.drain_time, 1.0);
    assert_eq!(hysteresis.recarve.proactive_recarves, 0);
    // forecast: the t=4 video flips the EWMA mix (share ≈ 0.64 ≥ the
    // 0.5 dominance bar), the window short-circuits while the pod is
    // still idle — zero drain, every video serves under its carve
    assert_eq!(forecast.metrics.horizon, 7.5);
    assert_eq!(forecast.recarve.drain_time, 0.0);
    assert_eq!(forecast.recarve.proactive_recarves, 1);
    assert!(
        forecast.metrics.horizon < hysteresis.metrics.horizon,
        "the forecast run must finish strictly sooner"
    );
    assert_eq!(forecast.recarve.recarve_count, hysteresis.recarve.recarve_count);

    // with the knob off, Forecast has no forecaster to consult: it
    // degrades to plain hysteresis, byte for byte
    let silent = run(RecarvePolicy::Forecast { threshold: 0.5, window: 2 }, None);
    assert_eq!(to_string(&silent.to_json()), to_string(&hysteresis.to_json()));
    assert_eq!(silent.recarve.proactive_recarves, 0);
}

/// Scripted split-pod model for the cost-gated absorb: flux prefers
/// the wide 4-machine replica carve and costs 3 s under any main-
/// generation epoch (but cannot run on the video side carve at all);
/// videos prefer a full-pod plan, subset-plan onto a 3-machine side
/// carve (1 s there, 2 s anywhere else), and every gain prediction
/// clears the threshold.
struct SplitStub;

impl SplitStub {
    fn wide() -> ParallelSpec {
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }

    fn video_pref() -> ParallelSpec {
        ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
    }

    fn side3() -> ParallelSpec {
        ParallelSpec::with_pp(1, 3, 1, SpDegrees::new(8, 1))
    }
}

impl CostModel for SplitStub {
    fn service_time(&self, w: &Workload, batch: usize) -> f64 {
        self.service_time_under(w, batch, None)
    }

    fn service_time_under(
        &self,
        w: &Workload,
        batch: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        if w.name.starts_with("flux") {
            if carve.copied() == Some(Self::side3()) {
                f64::INFINITY
            } else {
                3.0 * batch as f64
            }
        } else if carve.copied() == Some(Self::side3()) {
            1.0 * batch as f64
        } else {
            2.0 * batch as f64
        }
    }
}

impl Planner for SplitStub {
    fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
        if w.name.starts_with("flux") {
            Some(Self::wide())
        } else {
            Some(Self::video_pref())
        }
    }

    fn recarve_gain(&self, _w: &Workload, _from: &ParallelSpec) -> Option<f64> {
        Some(0.75)
    }

    fn plan_spec_on(&self, w: &Workload, machines: usize) -> Option<ParallelSpec> {
        if !w.name.starts_with("flux") && machines == 3 {
            Some(Self::side3())
        } else {
            None
        }
    }

    fn partial_recarve_gain(
        &self,
        _w: &Workload,
        _from: &ParallelSpec,
        _idle: usize,
    ) -> Option<f64> {
        Some(0.75)
    }
}

/// The cost-gated merge: a lone video splits a 3-machine side carve
/// off the flux pod; the flux stream keeps the main generation busy
/// back to back, so the full-idle merge barrier can never fire and a
/// forecast-less pod stays split past the end of the trace. With the
/// forecaster on, the t=3 flux dispatch still holds the gate (the
/// video's EWMA share is ≈ 0.12, above the absorb epsilon), and the
/// t=4 dispatch fires it: the side's class has faded from the mix, the
/// main-busy pod absorbs the drained side for exactly one re-setup,
/// and the pod finishes the trace re-unified.
#[test]
fn forecast_gated_absorb_reunifies_a_main_busy_split_pod() {
    let mk = |id: u64, w: Workload, at: f64| Request { id, workload: w, arrival: at, seed: id };
    let run = |forecast: bool| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let mut config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .recarve(RecarvePolicy::Partial { threshold: 0.5, window: 1 })
            .recarve_setup(0.25);
        if forecast {
            config = config.forecast_window(0.5);
        }
        let trace = vec![
            mk(0, Workload::flux_3072(), 0.0),
            mk(1, Workload::flux_3072(), 1.0),
            mk(2, Workload::cogvideo_20s(), 2.0),
            mk(3, Workload::flux_3072(), 3.0),
            mk(4, Workload::flux_3072(), 4.0),
        ];
        ServeSession::new(config, &SplitStub).run(&mut router, trace)
    };
    let frozen = run(false);
    let gated = run(true);

    assert_eq!(frozen.metrics.completed(), 5);
    assert_eq!(gated.metrics.completed(), 5);
    assert!(frozen.rejected.is_empty() && gated.rejected.is_empty());
    assert_eq!(frozen.recarve.partial_splits, 1);
    assert_eq!(gated.recarve.partial_splits, 1);

    // without a forecaster the split outlives the trace: the main
    // generation never idles, so the merge barrier cannot fire
    assert_eq!(frozen.recarve.merges, 0);
    assert_eq!(frozen.recarve.group_epochs[0].1.merged_at, None);

    // the gate held at t=3 and fired at t=4 — the absorb timestamp is
    // the proof the decision was forecast-driven, not drain-driven
    assert_eq!(gated.recarve.merges, 1);
    assert_eq!(gated.recarve.group_epochs[0].1.merged_at, Some(4.0));
    assert_eq!(gated.recarve.group_epochs[0].1.served, 1, "the side served the video");
    assert!(to_string(&gated.to_json()).contains("\"merges\":1"));

    // exact accounting: absorbing charges one side-teardown re-setup
    // (0.25 s) to the main timeline — the whole price of handing the 3
    // side machines back while the main keeps computing. (The *payoff*
    // — a wider footprint for later re-carves — needs a
    // footprint-aware cost model; `benches/fig_forecast.rs` shows it
    // end to end.)
    assert_eq!(gated.metrics.horizon, frozen.metrics.horizon + 0.25);
    assert_eq!(gated.recarve.setup_time, frozen.recarve.setup_time + 0.25);
}

// ---------------------------------------------------------------------------
// Replica co-batching
// ---------------------------------------------------------------------------

/// Scripted model with hand-computable times: prefers a 4-replica carve
/// and costs `1 + batch` seconds per dispatch.
struct RepService;

impl RepService {
    fn spec() -> ParallelSpec {
        // cfg1 x pp1 x rep4 x U8R1 on the 4x8 testbed
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }
}

impl CostModel for RepService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        1.0 + batch as f64
    }
}

impl Planner for RepService {
    fn plan_spec(&self, _w: &Workload) -> Option<ParallelSpec> {
        Some(Self::spec())
    }
}

fn burst(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: short_workload(),
            arrival: i as f64 * 0.1,
            seed: i as u64,
        })
        .collect()
}

/// The co-batching arithmetic, exactly: a batch of 8 on a 4-replica
/// carve scatters into shards of 2, so the dispatch costs `1 + 2`
/// instead of `1 + 8` seconds — throughput up, every request's latency
/// bounded by its non-co-batched latency.
#[test]
fn co_batching_scatters_a_batch_across_replica_groups() {
    let run = |co_batch: bool| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 8, window: 1.0 })
            .co_batch(co_batch);
        ServeSession::new(config, &RepService).run(&mut router, burst(8))
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.metrics.completed(), 8);
    assert_eq!(on.metrics.completed(), 8);
    // one full batch closes at t = 0.7 in both runs
    assert_eq!(off.co_batched, 0);
    assert_eq!(on.co_batched, 1);
    assert_eq!(off.metrics.horizon, 0.7 + 9.0, "whole batch on one group");
    assert_eq!(on.metrics.horizon, 0.7 + 3.0, "shards of 2 across 4 groups");
    // per-request latency bounded: co-batching never makes a request slower
    for ((id_on, arr_on, done_on), (id_off, arr_off, done_off)) in
        on.completions.iter().zip(off.completions.iter())
    {
        assert_eq!((id_on, arr_on), (id_off, arr_off));
        assert!(done_on - arr_on <= done_off - arr_off + 1e-12);
    }
    // observability: the count serializes only when the feature fired
    assert!(to_string(&on.to_json()).contains("\"co_batched\":1"));
    assert!(!to_string(&off.to_json()).contains("co_batched"));
}

/// Same claim through the real timing model: an auto-planned short-image
/// burst lands on a replica carve (`rep4` on the 4x8 testbed), and
/// co-batching the closed batches across those replica groups finishes
/// the burst sooner than queueing each batch on one group.
#[test]
fn co_batched_short_image_burst_beats_the_pr3_baseline() {
    let run = |co_batch: bool| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 8, window: 1.0 })
            .plan(PlanPolicy::Auto)
            .co_batch(co_batch);
        ServeSession::new(config, &svc).run(&mut router, burst(16))
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.metrics.completed(), 16);
    assert_eq!(on.metrics.completed(), 16);
    assert!(on.co_batched >= 1, "the replica carve must trigger scattering");
    assert!(
        on.metrics.horizon < off.metrics.horizon,
        "co-batched burst {} must beat one-group batches {}",
        on.metrics.horizon,
        off.metrics.horizon
    );
    // the plan histogram shows the replica carve both runs served under
    assert!(
        on.plan_histogram.keys().any(|k| k.contains("rep4")),
        "expected a replica plan: {:?}",
        on.plan_histogram
    );
}

// ---------------------------------------------------------------------------
// Cross-pod re-balancing
// ---------------------------------------------------------------------------

/// Drifting pod-mix trace: a short-image phase (1 Hz) followed by
/// sparse long CFG videos (one every 10 s, far above their service
/// time, so the fleet always has an idle donor).
fn drifting_trace(shorts: usize, videos: usize) -> Vec<Request> {
    let mut reqs = Vec::new();
    for i in 0..shorts {
        reqs.push(Request {
            id: i as u64,
            workload: short_workload(),
            arrival: i as f64,
            seed: i as u64,
        });
    }
    for i in 0..videos {
        let id = (shorts + i) as u64;
        reqs.push(Request {
            id,
            workload: long_workload(),
            arrival: shorts as f64 + 10.0 + i as f64 * 10.0,
            seed: id,
        });
    }
    reqs
}

/// The drifting-mix claim: when traffic shifts to long CFG videos, a
/// fleet that migrates an idle machine toward the video pod (2+2 → 3+1
/// on machines of 8 GPUs) serves the videos faster than the frozen 2+2
/// fleet — the 24-GPU pod affords a carve no 16-GPU pod can hold
/// (one-machine pipeline stages over three machines, at 16 patches so
/// the pipeline fill is well amortized), while the short images are
/// indifferent (their one-machine carve exists on every footprint).
#[test]
fn cross_pod_rebalancing_beats_the_frozen_fleet_on_a_drifting_mix() {
    let run = |rebalance: RebalancePolicy| {
        // 4 machines x 8 GPUs, two pods of 2 machines each
        let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        let fleet = SimFleet::auto(SpAlgo::SwiftFusion, 16);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .plan(PlanPolicy::Auto)
            .patches(16)
            .dispatch(Arc::new(EarliestFinish))
            .rebalance(rebalance);
        let report =
            ServeSession::with_fleet(config, &fleet).run(&mut router, drifting_trace(6, 8));
        let machines: Vec<usize> = router.pods.iter().map(|p| p.cluster.machines).collect();
        (report, machines)
    };
    let (frozen, frozen_machines) = run(RebalancePolicy::Never);
    let (adaptive, adaptive_machines) =
        run(RebalancePolicy::Gain { threshold: 0.1, window: 2 });

    assert_eq!(frozen.metrics.completed(), 14);
    assert_eq!(adaptive.metrics.completed(), 14);
    assert_eq!(frozen_machines, vec![2, 2], "never keeps the admission fleet");
    assert!(frozen.rebalances.is_empty());

    // the shift fired exactly one migration toward the video pod
    assert_eq!(adaptive.rebalances.len(), 1, "{:?}", adaptive.rebalances);
    let ev = &adaptive.rebalances[0];
    assert_eq!(ev.to_machines, 3);
    assert_eq!(ev.from_machines, 1);
    assert_eq!(adaptive_machines.iter().sum::<usize>(), 4, "no machine lost");
    assert!(adaptive_machines.contains(&3) && adaptive_machines.contains(&1));

    // and it paid off: videos served faster, fleet finishes sooner
    let mut frozen_m = frozen.metrics;
    let mut adaptive_m = adaptive.metrics;
    let name = long_workload().name;
    let frozen_video = frozen_m.latency(name).unwrap().mean();
    let adaptive_video = adaptive_m.latency(name).unwrap().mean();
    assert!(
        adaptive_video < frozen_video,
        "video latency: adaptive {adaptive_video} must beat frozen {frozen_video}"
    );
    assert!(adaptive_m.horizon < frozen_m.horizon);

    // observability: the migration serializes (only) when it happened
    assert!(to_string(&adaptive.to_json()).contains("\"rebalance\":["));
    assert!(!to_string(&frozen.to_json()).contains("\"rebalance\""));
}

// ---------------------------------------------------------------------------
// Quality-elastic serving
// ---------------------------------------------------------------------------

/// Flat-cost scripted model: every dispatch costs `2 · batch` seconds
/// regardless of workload, so the quality ladder's time factors are the
/// only thing that can change a completion time.
struct Flat;

impl CostModel for Flat {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        2.0 * batch as f64
    }
}

impl Planner for Flat {}

fn quality_run(config: ServeConfig) -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    ServeSession::new(config, &Flat).run(&mut router, burst(4))
}

/// The byte-identity contract: with both quality knobs unset nothing
/// quality-related reaches the report (the PR-3 golden above already
/// pins the exact bytes); with the knob on but the floor at 1.0 every
/// batch still serves Full — durations are bit-identical (×1.0 is exact
/// in IEEE arithmetic) and the *only* addition is the quality histogram.
#[test]
fn quality_knob_off_is_byte_identical_and_floor_one_only_adds_the_histogram() {
    let base = || ServeConfig::new().batch(BatchPolicy { max_batch: 1, window: 0.0 });
    let off = quality_run(base());
    assert!(off.quality_histogram.is_empty());
    let json_off = to_string(&off.to_json());
    assert!(!json_off.contains("quality"), "knob-off report must not mention quality");

    let full = quality_run(base().quality_floor(1.0));
    assert_eq!(off.completions, full.completions, "x1.0 durations are bit-identical");
    assert_eq!(
        off.metrics.horizon.to_bits(),
        full.metrics.horizon.to_bits(),
        "bit-identical horizon under floor 1.0"
    );
    assert_eq!(full.quality_histogram.get("full"), Some(&4));
    assert!(to_string(&full.to_json()).contains("\"quality_histogram\":{\"full\":4}"));
    // the config line advertises the knob (and only then)
    assert!(!base().summary().contains("quality"));
    assert!(base().quality_floor(1.0).summary().ends_with("quality-floor=1"));
}

/// The admission flow itself: under a 0.9 floor the first burst batch
/// lands on an idle pod (Full), every later batch sees the backlog and
/// degrades to Displaced — the cheapest mode at or above the floor —
/// clearing the burst strictly faster than forced full quality, with the
/// histogram recording the flip.
#[test]
fn quality_floor_flips_backlogged_batches_to_displaced() {
    let base = || ServeConfig::new().batch(BatchPolicy { max_batch: 1, window: 0.0 });
    let floored = quality_run(base().quality_floor(0.9));
    let forced_full = quality_run(base().quality(QualityMode::Full));

    assert_eq!(floored.metrics.completed(), 4);
    assert_eq!(forced_full.metrics.completed(), 4);
    assert_eq!(floored.quality_histogram.get("full"), Some(&1), "idle pod serves exact");
    assert_eq!(
        floored.quality_histogram.get("displaced"),
        Some(&3),
        "every backlogged batch flipped: {:?}",
        floored.quality_histogram
    );
    assert_eq!(forced_full.quality_histogram.get("full"), Some(&4));

    // exact arithmetic: r0 serves 2 s at full quality, r1..r3 queue and
    // serve 2 · DISPLACED_TIME_FACTOR each, back to back from t = 2
    let expected = 2.0 + 3.0 * (2.0 * DISPLACED_TIME_FACTOR);
    assert!(
        (floored.metrics.horizon - expected).abs() < 1e-12,
        "floored horizon {} vs expected {expected}",
        floored.metrics.horizon
    );
    assert_eq!(forced_full.metrics.horizon, 8.0, "four 2 s dispatches back to back");
    assert!(
        floored.metrics.horizon < forced_full.metrics.horizon,
        "the floor must clear the burst strictly faster"
    );
    // serialization: BTreeMap orders the mode labels
    assert!(to_string(&floored.to_json())
        .contains("\"quality_histogram\":{\"displaced\":3,\"full\":1}"));
}

/// Forced step reduction prices through the workload's distillation
/// arithmetic: the shrunk image workload (2 steps × 1 eval) halves to 1
/// eval under `steps/2`, so every dispatch costs exactly half.
#[test]
fn forced_reduced_steps_halves_the_flat_cost_run() {
    let base = || ServeConfig::new().batch(BatchPolicy { max_batch: 1, window: 0.0 });
    let reduced = quality_run(base().quality(QualityMode::ReducedSteps { factor: 2 }));
    assert_eq!(reduced.metrics.completed(), 4);
    assert_eq!(reduced.quality_histogram.get("steps/2"), Some(&4));
    assert_eq!(
        reduced.metrics.horizon, 4.0,
        "four 1 s dispatches back to back (2 s x the 0.5 eval ratio)"
    );
}

// ---------------------------------------------------------------------------
// Batcher flush-deadline edge, at the serving-loop level
// ---------------------------------------------------------------------------

/// A request arriving exactly at the head request's window deadline must
/// join the closing batch (the loop pushes the arrival before sweeping
/// the batcher), not strand in the queue until the end-of-trace flush.
#[test]
fn deadline_arrival_joins_the_closing_batch_not_the_flush() {
    struct Unit;
    impl CostModel for Unit {
        fn service_time(&self, _w: &Workload, _b: usize) -> f64 {
            1.0
        }
    }
    impl Planner for Unit {}
    let reqs = vec![
        Request { id: 0, workload: short_workload(), arrival: 0.0, seed: 0 },
        Request { id: 1, workload: short_workload(), arrival: 2.0, seed: 1 },
    ];
    let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 4, window: 2.0 },
        reqs,
        &Unit,
    );
    assert_eq!(report.metrics.completed(), 2);
    // one shared dispatch at t=2 (flat 1s service): both done at t=3 —
    // a stranded r1 would instead complete in a second 1s slot at t=4
    assert_eq!(report.completions[0].2, 3.0);
    assert_eq!(report.completions[1].2, 3.0);
    assert_eq!(report.metrics.horizon, 3.0);
}
