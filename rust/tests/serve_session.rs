//! Integration regressions for the `ServeSession` scheduler redesign:
//!
//! * the legacy `serve()` entry point is a thin shim over `ServeSession`
//!   and must reproduce it **bit-for-bit** on the bimodal re-carving
//!   trace (golden `ServeReport::to_json` parity);
//! * replica co-batching: replica groups serve one shared batch —
//!   throughput up, per-request latency bounded (exact arithmetic under
//!   a scripted model, and a real `SimService` burst);
//! * cross-pod re-balancing: on a drifting pod-mix trace, migrating an
//!   idle machine toward the video pod beats the frozen 2+2 fleet;
//! * the batcher flush-deadline edge at the serving-loop level.

use std::sync::Arc;

use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::config::{ParallelSpec, SpDegrees};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{serve, PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    EarliestFinish, RebalancePolicy, ServeConfig, ServeSession, SimFleet,
};
use swiftfusion::coordinator::{CostModel, Planner};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{bimodal_trace, Request, Workload};

/// The recarve-bench workload pair, shrunk (2 layers × 2 steps) so the
/// timing simulations stay fast — same shapes the engine unit tests use.
fn short_workload() -> Workload {
    let mut w = Workload::short_image_4k();
    w.layers = 2;
    w.steps = 2;
    w
}

fn long_workload() -> Workload {
    let mut w = Workload::cfg_video_96k();
    w.layers = 2;
    w.steps = 2;
    w
}

// ---------------------------------------------------------------------------
// Golden parity: legacy serve() shim vs ServeSession
// ---------------------------------------------------------------------------

/// Legacy entry (router setters + `serve()`) vs the new API
/// (`ServeConfig` + `ServeSession`) on the bimodal re-carving trace:
/// identical completions, bit-identical horizon, and byte-identical
/// `to_json` — the redesign may not perturb a single result.
#[test]
fn serve_session_matches_legacy_serve_bit_for_bit() {
    let trace = || bimodal_trace(&short_workload(), &long_workload(), 3, 6);
    let policy = RecarvePolicy::Hysteresis { threshold: 0.05, window: 2 };
    let batch = BatchPolicy { max_batch: 1, window: 0.0 };

    let legacy: ServeReport = {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(policy, 0.01);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        serve(&mut router, batch.clone(), trace(), &svc)
    };
    let session: ServeReport = {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(batch.clone())
            .plan(PlanPolicy::Auto)
            .recarve(policy)
            .recarve_setup(0.01);
        ServeSession::new(config, &svc).run(&mut router, trace())
    };

    assert_eq!(legacy.completions, session.completions, "bit-for-bit completions");
    assert_eq!(
        legacy.metrics.horizon.to_bits(),
        session.metrics.horizon.to_bits(),
        "bit-for-bit horizon"
    );
    assert_eq!(legacy.rejected, session.rejected);
    assert_eq!(legacy.plan_histogram, session.plan_histogram);
    assert_eq!(legacy.recarve.recarve_count, session.recarve.recarve_count);
    assert_eq!(
        to_string(&legacy.to_json()),
        to_string(&session.to_json()),
        "byte-identical serialized reports"
    );
    // the adaptive run actually exercised the epoch machinery
    assert!(legacy.recarve.recarve_count >= 1);
    // and neither new capability leaked into a default-config run
    assert!(legacy.rebalances.is_empty() && session.rebalances.is_empty());
    assert_eq!((legacy.co_batched, session.co_batched), (0, 0));
    assert!(!to_string(&session.to_json()).contains("rebalance\":["));
    assert!(!to_string(&session.to_json()).contains("co_batched"));
}

/// The one deliberate observable change of the shim: completions are
/// recorded in completion-time order. On multiple pods a later dispatch
/// can finish first — the report must order by completion, not
/// dispatch, and still account every request exactly once.
#[test]
fn multi_pod_completions_are_in_completion_time_order() {
    struct PerWorkload;
    impl CostModel for PerWorkload {
        fn service_time(&self, w: &Workload, _b: usize) -> f64 {
            // videos take far longer than images
            if w.name.starts_with("cfg-video") { 10.0 } else { 1.0 }
        }
    }
    impl Planner for PerWorkload {}
    // video dispatched first (pod 0), image right after (pod 1): the
    // image completes first and must lead the completions vec
    let reqs = vec![
        Request { id: 0, workload: long_workload(), arrival: 0.0, seed: 0 },
        Request { id: 1, workload: short_workload(), arrival: 0.1, seed: 1 },
    ];
    let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 1, window: 0.0 },
        reqs,
        &PerWorkload,
    );
    assert_eq!(report.metrics.completed(), 2);
    let ids: Vec<u64> = report.completions.iter().map(|c| c.0).collect();
    assert_eq!(ids, vec![1, 0], "image (done 1.1) precedes video (done 10.0)");
    let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
    assert!(dones.windows(2).all(|w| w[0] <= w[1]));
}

// ---------------------------------------------------------------------------
// Replica co-batching
// ---------------------------------------------------------------------------

/// Scripted model with hand-computable times: prefers a 4-replica carve
/// and costs `1 + batch` seconds per dispatch.
struct RepService;

impl RepService {
    fn spec() -> ParallelSpec {
        // cfg1 x pp1 x rep4 x U8R1 on the 4x8 testbed
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }
}

impl CostModel for RepService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        1.0 + batch as f64
    }
}

impl Planner for RepService {
    fn plan_spec(&self, _w: &Workload) -> Option<ParallelSpec> {
        Some(Self::spec())
    }
}

fn burst(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: short_workload(),
            arrival: i as f64 * 0.1,
            seed: i as u64,
        })
        .collect()
}

/// The co-batching arithmetic, exactly: a batch of 8 on a 4-replica
/// carve scatters into shards of 2, so the dispatch costs `1 + 2`
/// instead of `1 + 8` seconds — throughput up, every request's latency
/// bounded by its non-co-batched latency.
#[test]
fn co_batching_scatters_a_batch_across_replica_groups() {
    let run = |co_batch: bool| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 8, window: 1.0 })
            .co_batch(co_batch);
        ServeSession::new(config, &RepService).run(&mut router, burst(8))
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.metrics.completed(), 8);
    assert_eq!(on.metrics.completed(), 8);
    // one full batch closes at t = 0.7 in both runs
    assert_eq!(off.co_batched, 0);
    assert_eq!(on.co_batched, 1);
    assert_eq!(off.metrics.horizon, 0.7 + 9.0, "whole batch on one group");
    assert_eq!(on.metrics.horizon, 0.7 + 3.0, "shards of 2 across 4 groups");
    // per-request latency bounded: co-batching never makes a request slower
    for ((id_on, arr_on, done_on), (id_off, arr_off, done_off)) in
        on.completions.iter().zip(off.completions.iter())
    {
        assert_eq!((id_on, arr_on), (id_off, arr_off));
        assert!(done_on - arr_on <= done_off - arr_off + 1e-12);
    }
    // observability: the count serializes only when the feature fired
    assert!(to_string(&on.to_json()).contains("\"co_batched\":1"));
    assert!(!to_string(&off.to_json()).contains("co_batched"));
}

/// Same claim through the real timing model: an auto-planned short-image
/// burst lands on a replica carve (`rep4` on the 4x8 testbed), and
/// co-batching the closed batches across those replica groups finishes
/// the burst sooner than queueing each batch on one group.
#[test]
fn co_batched_short_image_burst_beats_the_pr3_baseline() {
    let run = |co_batch: bool| {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 8, window: 1.0 })
            .plan(PlanPolicy::Auto)
            .co_batch(co_batch);
        ServeSession::new(config, &svc).run(&mut router, burst(16))
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.metrics.completed(), 16);
    assert_eq!(on.metrics.completed(), 16);
    assert!(on.co_batched >= 1, "the replica carve must trigger scattering");
    assert!(
        on.metrics.horizon < off.metrics.horizon,
        "co-batched burst {} must beat one-group batches {}",
        on.metrics.horizon,
        off.metrics.horizon
    );
    // the plan histogram shows the replica carve both runs served under
    assert!(
        on.plan_histogram.keys().any(|k| k.contains("rep4")),
        "expected a replica plan: {:?}",
        on.plan_histogram
    );
}

// ---------------------------------------------------------------------------
// Cross-pod re-balancing
// ---------------------------------------------------------------------------

/// Drifting pod-mix trace: a short-image phase (1 Hz) followed by
/// sparse long CFG videos (one every 10 s, far above their service
/// time, so the fleet always has an idle donor).
fn drifting_trace(shorts: usize, videos: usize) -> Vec<Request> {
    let mut reqs = Vec::new();
    for i in 0..shorts {
        reqs.push(Request {
            id: i as u64,
            workload: short_workload(),
            arrival: i as f64,
            seed: i as u64,
        });
    }
    for i in 0..videos {
        let id = (shorts + i) as u64;
        reqs.push(Request {
            id,
            workload: long_workload(),
            arrival: shorts as f64 + 10.0 + i as f64 * 10.0,
            seed: id,
        });
    }
    reqs
}

/// The drifting-mix claim: when traffic shifts to long CFG videos, a
/// fleet that migrates an idle machine toward the video pod (2+2 → 3+1
/// on machines of 8 GPUs) serves the videos faster than the frozen 2+2
/// fleet — the 24-GPU pod affords a carve no 16-GPU pod can hold
/// (one-machine pipeline stages over three machines, at 16 patches so
/// the pipeline fill is well amortized), while the short images are
/// indifferent (their one-machine carve exists on every footprint).
#[test]
fn cross_pod_rebalancing_beats_the_frozen_fleet_on_a_drifting_mix() {
    let run = |rebalance: RebalancePolicy| {
        // 4 machines x 8 GPUs, two pods of 2 machines each
        let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        let fleet = SimFleet::auto(SpAlgo::SwiftFusion, 16);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .plan(PlanPolicy::Auto)
            .patches(16)
            .dispatch(Arc::new(EarliestFinish))
            .rebalance(rebalance);
        let report =
            ServeSession::with_fleet(config, &fleet).run(&mut router, drifting_trace(6, 8));
        let machines: Vec<usize> = router.pods.iter().map(|p| p.cluster.machines).collect();
        (report, machines)
    };
    let (frozen, frozen_machines) = run(RebalancePolicy::Never);
    let (adaptive, adaptive_machines) =
        run(RebalancePolicy::Gain { threshold: 0.1, window: 2 });

    assert_eq!(frozen.metrics.completed(), 14);
    assert_eq!(adaptive.metrics.completed(), 14);
    assert_eq!(frozen_machines, vec![2, 2], "never keeps the admission fleet");
    assert!(frozen.rebalances.is_empty());

    // the shift fired exactly one migration toward the video pod
    assert_eq!(adaptive.rebalances.len(), 1, "{:?}", adaptive.rebalances);
    let ev = &adaptive.rebalances[0];
    assert_eq!(ev.to_machines, 3);
    assert_eq!(ev.from_machines, 1);
    assert_eq!(adaptive_machines.iter().sum::<usize>(), 4, "no machine lost");
    assert!(adaptive_machines.contains(&3) && adaptive_machines.contains(&1));

    // and it paid off: videos served faster, fleet finishes sooner
    let mut frozen_m = frozen.metrics;
    let mut adaptive_m = adaptive.metrics;
    let name = long_workload().name;
    let frozen_video = frozen_m.latency(name).unwrap().mean();
    let adaptive_video = adaptive_m.latency(name).unwrap().mean();
    assert!(
        adaptive_video < frozen_video,
        "video latency: adaptive {adaptive_video} must beat frozen {frozen_video}"
    );
    assert!(adaptive_m.horizon < frozen_m.horizon);

    // observability: the migration serializes (only) when it happened
    assert!(to_string(&adaptive.to_json()).contains("\"rebalance\":["));
    assert!(!to_string(&frozen.to_json()).contains("\"rebalance\""));
}

// ---------------------------------------------------------------------------
// Batcher flush-deadline edge, at the serving-loop level
// ---------------------------------------------------------------------------

/// A request arriving exactly at the head request's window deadline must
/// join the closing batch (the loop pushes the arrival before sweeping
/// the batcher), not strand in the queue until the end-of-trace flush.
#[test]
fn deadline_arrival_joins_the_closing_batch_not_the_flush() {
    struct Unit;
    impl CostModel for Unit {
        fn service_time(&self, _w: &Workload, _b: usize) -> f64 {
            1.0
        }
    }
    impl Planner for Unit {}
    let reqs = vec![
        Request { id: 0, workload: short_workload(), arrival: 0.0, seed: 0 },
        Request { id: 1, workload: short_workload(), arrival: 2.0, seed: 1 },
    ];
    let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 4, window: 2.0 },
        reqs,
        &Unit,
    );
    assert_eq!(report.metrics.completed(), 2);
    // one shared dispatch at t=2 (flat 1s service): both done at t=3 —
    // a stranded r1 would instead complete in a second 1s slot at t=4
    assert_eq!(report.completions[0].2, 3.0);
    assert_eq!(report.completions[1].2, 3.0);
    assert_eq!(report.metrics.horizon, 3.0);
}
