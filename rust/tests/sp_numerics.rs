//! Integration: every SP algorithm × mesh configuration must reproduce
//! single-device attention *exactly* (≤1e-4 in f32), with real tensors
//! flowing between rank threads and all tile math running through the
//! AOT Pallas artifacts. This is the core correctness claim of the repo:
//! Ring, Ulysses, USP, TAS, Torus(NCCL), and SwiftFusion (Algorithm 1)
//! are all *exact* attention algorithms — only their communication
//! schedules differ.

use std::sync::Arc;

use swiftfusion::cluster::exec::{run_cluster, run_in_world, ExecMode};
use swiftfusion::comm::{Buf, CommWorld};
use swiftfusion::config::{AttnShape, ClusterSpec, SpDegrees};
use swiftfusion::runtime::Runtime;
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::tensor::Tensor;

struct Fixture {
    rt: Runtime,
}

/// Skip (not fail) when PJRT or the artifacts are unavailable — the
/// hermetic numeric coverage of the same algorithms lives in
/// `sp_property.rs` (host tile kernels, no artifacts needed).
macro_rules! fixture_or_skip {
    () => {
        match Fixture::maybe() {
            Some(f) => f,
            None => return,
        }
    };
}

impl Fixture {
    fn maybe() -> Option<Self> {
        Runtime::load_default_if_available().map(|rt| Self { rt })
    }

    /// Run `algo` on `cfg_name` with mesh (n, m, pu) and compare every
    /// rank's output shard against the single-device oracle artifact.
    fn check(&self, cfg_name: &str, algo: SpAlgo, n: usize, m: usize, pu: usize) {
        let cfg = Arc::new(self.rt.manifest().config(cfg_name).unwrap().clone());
        let total = n * m;
        assert_eq!(total, cfg.mesh, "test mesh must match config mesh");
        let cluster = ClusterSpec::new(n, m);
        let shape = AttnShape::new(cfg.b, cfg.l, cfg.h, cfg.d);
        let params = SpParams {
            shape,
            chunk: cfg.chunk,
            mesh: algo.mesh(&cluster, SpDegrees::new(pu, total / pu)),
        };

        let q = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 1000);
        let k = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 2000);
        let v = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 3000);

        let oracle = self
            .rt
            .handle()
            .call(
                &format!("attn_full_{cfg_name}"),
                &[q.clone(), k.clone(), v.clone()],
            )
            .unwrap()
            .remove(0);

        let mode = ExecMode::Numeric { rt: self.rt.handle(), cfg: Arc::clone(&cfg) };
        let ls = cfg.l / total;
        let run = run_cluster(&cluster, &mode, |ctx| {
            let r = ctx.rank;
            let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
            let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
            let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
            algo.run(ctx, &params, qs, ks, vs).into_tensor()
        });

        for (rank, got) in run.outputs.iter().enumerate() {
            let want = oracle.slice(1, rank * ls, (rank + 1) * ls).unwrap();
            let diff = got.max_abs_diff(&want);
            assert!(
                diff < 1e-4,
                "{} on {cfg_name} mesh {n}x{m} pu={pu}: rank {rank} diff {diff}",
                algo.name()
            );
        }
        assert!(run.makespan() > 0.0, "virtual time must advance");
    }
}

// ---- small4: 4 ranks (2 machines x 2 GPUs), H=4 --------------------------

#[test]
fn ring_small4() {
    fixture_or_skip!().check("small4", SpAlgo::Ring, 2, 2, 1);
}

#[test]
fn ulysses_small4() {
    fixture_or_skip!().check("small4", SpAlgo::Ulysses, 2, 2, 4);
}

#[test]
fn usp_small4() {
    fixture_or_skip!().check("small4", SpAlgo::Usp, 2, 2, 2);
}

#[test]
fn tas_small4() {
    fixture_or_skip!().check("small4", SpAlgo::Tas, 2, 2, 2);
}

#[test]
fn torus_nccl_small4() {
    fixture_or_skip!().check("small4", SpAlgo::TorusNccl, 2, 2, 2);
}

#[test]
fn swiftfusion_small4() {
    fixture_or_skip!().check("small4", SpAlgo::SwiftFusion, 2, 2, 2);
}

#[test]
fn swiftfusion_small4_full_ulysses() {
    // P_u = 4 (gcd rule with H=4): torus degree 2, P_u' = 2.
    fixture_or_skip!().check("small4", SpAlgo::SwiftFusion, 2, 2, 4);
}

// ---- small8: 8 ranks, H=8, B=2 -------------------------------------------

#[test]
fn ring_small8() {
    fixture_or_skip!().check("small8", SpAlgo::Ring, 4, 2, 1);
}

#[test]
fn ulysses_small8() {
    fixture_or_skip!().check("small8", SpAlgo::Ulysses, 2, 4, 8);
}

#[test]
fn usp_small8() {
    fixture_or_skip!().check("small8", SpAlgo::Usp, 4, 2, 2);
}

#[test]
fn usp_small8_u4() {
    fixture_or_skip!().check("small8", SpAlgo::Usp, 2, 4, 4);
}

#[test]
fn tas_small8() {
    fixture_or_skip!().check("small8", SpAlgo::Tas, 4, 2, 4);
}

#[test]
fn torus_nccl_small8() {
    fixture_or_skip!().check("small8", SpAlgo::TorusNccl, 4, 2, 4);
}

#[test]
fn swiftfusion_small8_gcd_rule() {
    // paper placement: P_u = gcd(8, 8) = 8 over 4 machines: T=4, P_u'=2,
    // exercising ScatterPush with a real intra-Ulysses dimension.
    fixture_or_skip!().check("small8", SpAlgo::SwiftFusion, 4, 2, 8);
}

#[test]
fn swiftfusion_small8_two_machines() {
    fixture_or_skip!().check("small8", SpAlgo::SwiftFusion, 2, 4, 4);
}

#[test]
fn swiftfusion_single_machine_degenerate() {
    // Paper §5.2: on one machine everything degrades to Ulysses-like
    // behaviour; SwiftFusion must still be exact.
    fixture_or_skip!().check("small8", SpAlgo::SwiftFusion, 1, 8, 8);
}

// ---- cross-algorithm consistency + Algorithm-1 sync structure ------------

#[test]
fn all_algorithms_agree_bitwise_closely() {
    // All six algorithms absorb KV chunks through the same tile kernel;
    // outputs may differ only by merge-order rounding (<1e-4 already
    // checked vs oracle). Here: pairwise agreement on one config.
    let f = fixture_or_skip!();
    let cfg = Arc::new(f.rt.manifest().config("small4").unwrap().clone());
    let cluster = ClusterSpec::new(2, 2);
    let q = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 1000);
    let k = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 2000);
    let v = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 3000);
    let ls = cfg.l / 4;

    let mut outputs: Vec<(String, Vec<Tensor>)> = Vec::new();
    for (algo, pu) in [
        (SpAlgo::Ring, 1),
        (SpAlgo::Ulysses, 4),
        (SpAlgo::Usp, 2),
        (SpAlgo::SwiftFusion, 2),
    ] {
        let params = SpParams {
            shape: AttnShape::new(cfg.b, cfg.l, cfg.h, cfg.d),
            chunk: cfg.chunk,
            mesh: algo.mesh(&cluster, SpDegrees::new(pu, 4 / pu)),
        };
        let mode = ExecMode::Numeric { rt: f.rt.handle(), cfg: Arc::clone(&cfg) };
        let run = run_cluster(&cluster, &mode, |ctx| {
            let r = ctx.rank;
            let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
            let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
            let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
            algo.run(ctx, &params, qs, ks, vs).into_tensor()
        });
        outputs.push((algo.name().to_string(), run.outputs));
    }
    let (base_name, base) = &outputs[0];
    for (name, outs) in &outputs[1..] {
        for (rank, (a, b)) in base.iter().zip(outs).enumerate() {
            let diff = a.max_abs_diff(b);
            assert!(diff < 1e-4, "{base_name} vs {name} rank {rank}: {diff}");
        }
    }
}

#[test]
fn alg1_sync_structure_with_real_numerics() {
    // §4.4: during a real numeric run, SwiftFusion must issue exactly two
    // global barriers; every other barrier stays intra-machine.
    let f = fixture_or_skip!();
    let cfg = Arc::new(f.rt.manifest().config("small4").unwrap().clone());
    let cluster = ClusterSpec::new(2, 2);
    let params = SpParams {
        shape: AttnShape::new(cfg.b, cfg.l, cfg.h, cfg.d),
        chunk: cfg.chunk,
        mesh: SpAlgo::SwiftFusion.mesh(&cluster, SpDegrees::new(2, 2)),
    };
    let ls = cfg.l / 4;
    let world = CommWorld::new(cluster.clone());
    let mode = ExecMode::Numeric { rt: f.rt.handle(), cfg: Arc::clone(&cfg) };
    run_in_world(&world, &mode, |ctx| {
        let r = ctx.rank;
        let s = |seed: u64| {
            Buf::Real(
                Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], seed)
                    .slice(1, r * ls, (r + 1) * ls)
                    .unwrap(),
            )
        };
        SpAlgo::SwiftFusion.run(ctx, &params, s(1), s(2), s(3));
    });
    let hist = world.barrier_history();
    let global: Vec<_> = hist.iter().filter(|g| g.len() == 4).collect();
    assert_eq!(global.len(), 2, "exactly 2 global barriers: {hist:?}");
    for g in &hist {
        if g.len() < 4 {
            assert!(
                g.windows(2).all(|w| cluster.same_machine(w[0], w[1])),
                "intra-machine barrier expected: {g:?}"
            );
        }
    }
}
