//! Integration: the full DiT model — fused vs stage-wise vs distributed —
//! and the complete sampling loop through the artifacts.
//!
//! This proves the layers compose: embed/qkv/attention/post/final run as
//! separate per-rank artifacts under every SP algorithm and still produce
//! the single-device forward (≤1e-3 f32 across a 2-block model; error
//! accumulates through LayerNorms).

use swiftfusion::config::{ClusterSpec, SpDegrees};
use swiftfusion::model::DiTModel;
use swiftfusion::runtime::Runtime;
use swiftfusion::sp::SpAlgo;
use swiftfusion::tensor::Tensor;

/// Skip (not fail) when PJRT or the artifacts are unavailable.
macro_rules! model_or_skip {
    ($cfg:expr) => {
        match Runtime::load_default_if_available() {
            Some(rt) => {
                let m = DiTModel::new(rt.handle(), $cfg).unwrap();
                (rt, m)
            }
            None => return,
        }
    };
}

#[test]
fn stagewise_equals_fused() {
    let (_rt, m) = model_or_skip!("small4");
    let x = Tensor::random(&[m.cfg.b, m.cfg.l, m.cfg.c_in], 7);
    let t = Tensor::new(vec![m.cfg.b], vec![321.0; m.cfg.b]).unwrap();
    let fused = m.forward_single(&x, &t).unwrap();
    let staged = m.forward_stagewise(&x, &t).unwrap();
    let diff = fused.max_abs_diff(&staged);
    assert!(diff < 1e-3, "stagewise vs fused: {diff}");
}

#[test]
fn distributed_forward_matches_fused_all_algos() {
    let (_rt, m) = model_or_skip!("small4");
    let cluster = ClusterSpec::new(2, 2);
    let x = Tensor::random(&[m.cfg.b, m.cfg.l, m.cfg.c_in], 8);
    let t = Tensor::new(vec![m.cfg.b], vec![500.0; m.cfg.b]).unwrap();
    let fused = m.forward_single(&x, &t).unwrap();
    for (algo, pu) in [
        (SpAlgo::Ring, 1),
        (SpAlgo::Ulysses, 4),
        (SpAlgo::Usp, 2),
        (SpAlgo::Tas, 2),
        (SpAlgo::TorusNccl, 2),
        (SpAlgo::SwiftFusion, 2),
    ] {
        let (eps, run) = m
            .forward_distributed(&cluster, algo, SpDegrees::new(pu, 4 / pu), &x, &t)
            .unwrap();
        let diff = eps.max_abs_diff(&fused);
        assert!(diff < 1e-3, "{} distributed vs fused: {diff}", algo.name());
        assert!(run.makespan() > 0.0);
    }
}

#[test]
fn distributed_forward_small8() {
    let (_rt, m) = model_or_skip!("small8");
    let cluster = ClusterSpec::new(4, 2);
    let x = Tensor::random(&[m.cfg.b, m.cfg.l, m.cfg.c_in], 9);
    let t = Tensor::new(vec![m.cfg.b], vec![100.0; m.cfg.b]).unwrap();
    let fused = m.forward_single(&x, &t).unwrap();
    let (eps, _) = m
        .forward_distributed(&cluster, SpAlgo::SwiftFusion, SpDegrees::new(8, 1), &x, &t)
        .unwrap();
    let diff = eps.max_abs_diff(&fused);
    assert!(diff < 1e-3, "swiftfusion on small8: {diff}");
}

#[test]
fn sampling_loop_single_device() {
    let (_rt, m) = model_or_skip!("small4");
    let img = m.sample_single(1234, 4).unwrap();
    assert_eq!(img.shape(), &[m.cfg.b, m.cfg.l, 12]);
    assert!(img.is_finite());
    assert!(img.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
    // determinism
    let img2 = m.sample_single(1234, 4).unwrap();
    assert_eq!(img, img2);
    // Different seeds must diverge at the latent level. (The decoded
    // pixels can saturate the toy VAE's sigmoid — random weights + the
    // DDIM 1/sqrt(abar) amplification — so compare eps, not pixels.)
    let x_a = Tensor::random(&[m.cfg.b, m.cfg.l, m.cfg.c_in], 1234);
    let x_b = Tensor::random(&[m.cfg.b, m.cfg.l, m.cfg.c_in], 99);
    let t = Tensor::new(vec![m.cfg.b], vec![999.0; m.cfg.b]).unwrap();
    let ea = m.forward_single(&x_a, &t).unwrap();
    let eb = m.forward_single(&x_b, &t).unwrap();
    assert!(ea.max_abs_diff(&eb) > 1e-3, "different noise, different eps");
}

#[test]
fn distributed_sampling_matches_single_device() {
    // The end-to-end serving path: distributed sampling must produce the
    // SAME image as single-device sampling (same seeds, same math).
    let (_rt, m) = model_or_skip!("small4");
    let cluster = ClusterSpec::new(2, 2);
    let single = m.sample_single(777, 3).unwrap();
    let (dist, sim_time) = m
        .sample_distributed(&cluster, SpAlgo::SwiftFusion, SpDegrees::new(2, 2), 777, 3)
        .unwrap();
    let diff = single.max_abs_diff(&dist);
    assert!(diff < 1e-3, "distributed sampling diverged: {diff}");
    assert!(sim_time > 0.0, "simulated GPU time accumulates");
}
