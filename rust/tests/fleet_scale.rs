//! Determinism-at-scale regressions for the indexed scheduler path.
//!
//! `SchedulerMode::Indexed` (indexed event heap + memoized pricing +
//! `free_at`-pruned pod selection) is an *optimization*, not a policy
//! change: on any trace it must replay the naive binary-heap /
//! re-price-everything `Linear` reference **bit-for-bit** — same event
//! count, same `ServeReport::to_json`. These tests pin that equivalence
//! on traces large enough (10^4 requests) and feature-dense enough
//! (co-batching, partial re-carves, cross-pod re-balancing) that any
//! ordering or caching divergence has thousands of chances to surface.

use std::sync::Arc;

use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    EarliestFinish, RebalancePolicy, SchedulerMode, ServeConfig, ServeSession, SimFleet,
};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{TraceGen, Workload};

/// Shrunk workloads (2 layers × 2 steps, as the serve_session tests
/// use) so the timing simulations stay fast at 10^4 requests.
fn short_workload() -> Workload {
    let mut w = Workload::short_image_4k();
    w.layers = 2;
    w.steps = 2;
    w
}

fn image_workload() -> Workload {
    let mut w = Workload::flux_3072();
    w.layers = 2;
    w.steps = 2;
    w
}

fn video_workload() -> Workload {
    let mut w = Workload::cfg_video_96k();
    w.layers = 2;
    w.steps = 2;
    w
}

/// 10^4 Poisson requests over a four-pod fleet, with batching,
/// co-batching, and hysteresis re-carving all live.
fn run_fleet(mode: SchedulerMode) -> ServeReport {
    // 8 machines x 8 GPUs, four pods of 2 machines each
    let mut router = Router::new(8, 8, 4, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 4, window: 1.0 })
        .plan(PlanPolicy::Auto)
        .co_batch(true)
        .recarve(RecarvePolicy::Hysteresis { threshold: 0.15, window: 2 })
        .dispatch(Arc::new(EarliestFinish))
        .scheduler(mode);
    let reqs =
        TraceGen::new(11, 2.0, vec![short_workload(), image_workload()]).take(10_000);
    ServeSession::new(config, &svc).run(&mut router, reqs)
}

#[test]
fn indexed_scheduler_replays_ten_thousand_requests_bit_identically() {
    let a = run_fleet(SchedulerMode::Indexed);
    let b = run_fleet(SchedulerMode::Indexed);
    let c = run_fleet(SchedulerMode::Linear);
    assert!(a.metrics.completed() > 9_000, "the trace must mostly complete");
    assert_eq!(a.events, b.events);
    assert_eq!(a.events, c.events, "both modes must process identical event streams");
    let (ja, jb, jc) =
        (to_string(&a.to_json()), to_string(&b.to_json()), to_string(&c.to_json()));
    assert_eq!(ja, jb, "the indexed scheduler must be self-deterministic");
    assert_eq!(ja, jc, "indexed must replay the linear reference bit-for-bit");
}

/// Every scheduler client at once — partial (group-granular) re-carves,
/// replica co-batching, and `gain` re-balancing on a two-pod fleet with
/// a bimodal short/video mix — still bit-identical across modes.
fn run_feature_soup(mode: SchedulerMode) -> ServeReport {
    // 8 machines x 8 GPUs, two pods of 4 machines each
    let mut router = Router::new(8, 8, 2, SpAlgo::SwiftFusion);
    let fleet = SimFleet::auto(SpAlgo::SwiftFusion, 16);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 2, window: 0.5 })
        .plan(PlanPolicy::Auto)
        .patches(16)
        .co_batch(true)
        .recarve(RecarvePolicy::Partial { threshold: 0.15, window: 2 })
        .dispatch(Arc::new(EarliestFinish))
        .rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 })
        .scheduler(mode);
    let reqs = TraceGen::new(7, 1.0, vec![short_workload(), video_workload()]).take(500);
    ServeSession::with_fleet(config, &fleet).run(&mut router, reqs)
}

#[test]
fn feature_soup_is_bit_identical_across_scheduler_modes() {
    let lin = run_feature_soup(SchedulerMode::Linear);
    let idx = run_feature_soup(SchedulerMode::Indexed);
    assert!(lin.metrics.completed() > 400, "the trace must mostly complete");
    assert_eq!(lin.events, idx.events);
    assert_eq!(
        to_string(&lin.to_json()),
        to_string(&idx.to_json()),
        "indexed must replay the linear reference bit-for-bit"
    );
}
