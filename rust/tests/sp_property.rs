//! Property-based numeric validation of every SP algorithm, hermetically
//! (no PJRT, no artifacts): random `(B, L, H, D)` shapes and mesh
//! degrees, real tensors through the threaded cluster in
//! `ExecMode::HostNumeric` (in-process Algorithm-2 tile kernels), each
//! rank's output shard compared against the independent plain-softmax
//! oracle — including the *group-scoped* paths on carved sub-meshes that
//! the hybrid CFG×SP planner uses.
//!
//! Tolerance is 1e-4 in f32: the distributed schedules only reorder the
//! softmax merge, they never approximate.

use swiftfusion::cluster::exec::{run_cluster, run_in_world, ExecMode};
use swiftfusion::cluster::plan::ParallelPlan;
use swiftfusion::cluster::recarve::{EpochTracker, PolicyCtx, RecarvePolicy};
use swiftfusion::comm::{Buf, CommWorld};
use swiftfusion::config::{gcd, AttnShape, ClusterSpec, ParallelSpec, SpDegrees};
use swiftfusion::sp::displaced::{
    fastattn_attention, guided_displaced_generate, guided_displaced_step, DispParams,
};
use swiftfusion::sp::hybrid::{
    guidance_combine, guided_attention_distributed, guided_attention_oracle,
};
use swiftfusion::sp::pipefusion::{
    guided_pipefusion_generate, guided_pipefusion_oracle, guided_pipefusion_step,
    stacked_attention_oracle, PipeParams,
};
use swiftfusion::sp::tiles::host;
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::tensor::Tensor;
use swiftfusion::util::prop::{self, Gen};

const TOL: f32 = 1e-4;

/// Documented steady-state tolerance of the displaced patch pipeline:
/// with the latent drifting by `η·(eps − x)` per step (η = 0.05 below,
/// inputs in [-1, 1)), the one-step-stale KV differs from fresh KV by at
/// most one step of drift, and the attention output — a convex
/// combination of V rows — moves by the same order. 0.1 gives a ~10x
/// margin over the drift actually observed while still being far below
/// the O(1) signal magnitude, so a broken stale-KV path cannot hide.
const STALE_TOL: f32 = 0.1;
const STALE_ETA: f32 = 0.05;

/// Documented tolerance of the compressed inter-machine path
/// ([`swiftfusion::config::NetSpec::inter_compress`] = 0.5). Derivation:
/// the wire carries 16-bit payloads — a uniform symmetric grid with
/// 2^15 − 1 = 32767 levels over each buffer's max magnitude — so one hop
/// perturbs an element by at most `amax / (2 · 32767) ≈ 1.5e-5 · amax`.
/// With inputs in [-1, 1) a quantized K shard shifts each d-term logit
/// dot product by ≲ d · 1.5e-5 ≈ 1e-4 (d = 8 here), the softmax row it
/// feeds by the same order, and the output — a convex combination of
/// (also ≲ 1.5e-5-perturbed) V rows — by ~1e-4..1e-3 worst case across
/// the multi-hop schedules. 1e-2 gives a ~10x margin over that bound
/// while staying far below the O(1) signal magnitude and below the
/// exactness bar a *broken* quantizer (wrong scale, wrong level count)
/// would blow through.
const COMPRESS_TOL: f32 = 1e-2;

/// The FastAttn window fraction the quality ladder serves
/// ([`swiftfusion::config::QualityMode::ladder`]).
const FASTATTN_KEEP: f64 = 0.5;

/// Approximation ceiling of the FastAttn windowed path at `keep_ratio`
/// = 0.5. The windowed output is a renormalized softmax over the kept
/// keys, so per element `o_full − o_win = q_out · (dropped_avg −
/// window_avg)` where `q_out` is the dropped keys' softmax mass — with
/// the repo's [-1, 1) inputs that is strictly below `2 · q_out · vmax <
/// 2`. The *sharp* check below compares the distributed path against
/// the per-tile windowed oracle at the repo-wide 1e-4 bar; this
/// constant only pins the approximation drift to its theoretical
/// ceiling (observed ~0.1–0.3 on these shapes), so a windowing bug that
/// escapes renormalized softmax entirely — unbounded output, sign flip,
/// un-normalized weights — still fails.
const FASTATTN_TOL: f32 = 1.9;

fn rand_qkv(shape: &AttnShape, seed: u64) -> (Tensor, Tensor, Tensor) {
    let dims = [shape.b, shape.l, shape.h, shape.d];
    (
        Tensor::random(&dims, seed),
        Tensor::random(&dims, seed.wrapping_add(1)),
        Tensor::random(&dims, seed.wrapping_add(2)),
    )
}

/// Valid P_u for `algo` on a `ranks`-rank mesh with `h` heads, one picked
/// per case: Ring has no Ulysses dimension, Ulysses has only one, and
/// the 2D algorithms accept any divisor of gcd(ranks, h).
fn pick_pu(g: &mut Gen, algo: SpAlgo, ranks: usize, h: usize) -> usize {
    match algo {
        SpAlgo::Ring => 1,
        SpAlgo::Ulysses => ranks,
        _ => {
            let gg = gcd(ranks, h);
            let divs: Vec<usize> = (1..=gg).filter(|x| gg % x == 0).collect();
            *g.choose(&divs)
        }
    }
}

/// Run `algo` on the full `cluster` mesh and compare every rank's shard
/// against the oracle.
fn check_full_mesh(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    pu: usize,
    shape: AttnShape,
    chunk: usize,
    seed: u64,
) {
    let p = cluster.total_gpus();
    let (q, k, v) = rand_qkv(&shape, seed);
    let oracle = host::attention_oracle(&q, &k, &v);
    let params = SpParams {
        shape,
        chunk,
        mesh: algo.mesh(cluster, SpDegrees::new(pu, p / pu)),
    };
    let ls = shape.l / p;
    let run = run_cluster(cluster, &ExecMode::HostNumeric, |ctx| {
        let r = ctx.rank;
        let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
        let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
        let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
        algo.run(ctx, &params, qs, ks, vs).into_tensor()
    });
    for (rank, got) in run.outputs.iter().enumerate() {
        let want = oracle.slice(1, rank * ls, (rank + 1) * ls).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(
            diff < TOL,
            "{} on {}x{} pu={pu} shape {shape:?}: rank {rank} diff {diff}",
            algo.name(),
            cluster.machines,
            cluster.gpus_per_machine,
        );
    }
    assert!(run.makespan() > 0.0, "virtual time must advance");
}

#[test]
fn prop_all_algos_match_oracle_on_random_shapes() {
    prop::run(12, |g| {
        let (n, m) = *g.choose(&[(1, 1), (1, 2), (2, 1), (2, 2), (1, 4), (4, 1), (2, 4), (4, 2)]);
        let cluster = ClusterSpec::new(n, m);
        let p = n * m;
        // H a multiple of P so even mesh-wide Ulysses is valid
        let h = p * g.int(1, if p >= 4 { 1 } else { 2 });
        let d = *g.choose(&[4usize, 8]);
        let chunk = *g.choose(&[4usize, 8]);
        let shape = AttnShape::new(g.int(1, 2), p * chunk, h, d);
        for algo in SpAlgo::ALL {
            let pu = pick_pu(g, algo, p, h);
            check_full_mesh(&cluster, algo, pu, shape, chunk, g.seed ^ 0xA77);
        }
    });
}

#[test]
fn prop_cfg_parallel_carved_groups_match_guided_oracle() {
    // Random guided layers under cfg_degree=2 plans: each branch on its
    // own carved sub-mesh, merged by the guidance combine. Covers carves
    // whose groups span several machines (base-offset torus paths) and
    // carves with several groups per machine.
    prop::run(10, |g| {
        let (n, m) = *g.choose(&[(2, 1), (2, 2), (4, 1), (2, 4), (4, 2)]);
        let cluster = ClusterSpec::new(n, m);
        let group = n * m / 2;
        let h = group * g.int(1, if group >= 4 { 1 } else { 2 });
        let d = *g.choose(&[4usize, 8]);
        let chunk = *g.choose(&[4usize, 8]);
        let shape = AttnShape::new(1, group * chunk, h, d);
        let algo = *g.choose(&SpAlgo::ALL);
        let pu = pick_pu(g, algo, group, h);
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(pu, group / pu));
        assert!(spec.validate(&cluster).is_ok(), "{spec:?} on {n}x{m}");
        let plan = ParallelPlan::build(&cluster, spec, algo).unwrap();

        let cond = rand_qkv(&shape, g.seed ^ 0xC0);
        let uncond = rand_qkv(&shape, g.seed ^ 0xD0);
        let scale = g.f64(0.0, 10.0) as f32;
        let (got, makespan) = guided_attention_distributed(
            &plan,
            shape,
            chunk,
            &cond,
            &uncond,
            scale,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guided_attention_oracle(&cond, &uncond, scale).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(
            diff < TOL,
            "{} cfg2 on {n}x{m} (group {group}, pu {pu}): diff {diff}",
            algo.name()
        );
        assert!(makespan > 0.0);
    });
}

#[test]
fn cfg_parallel_two_by_two_all_algos_match_guided_oracle() {
    // The acceptance case, pinned (not randomized): a 2×2 simulated
    // cluster, cfg_degree=2, each branch on a group-scoped 2-rank SP
    // sub-mesh — every SpAlgo must reproduce the single-device
    // guided-sampling oracle within fp tolerance.
    let cluster = ClusterSpec::new(2, 2);
    let shape = AttnShape::new(2, 64, 4, 8);
    let cond = rand_qkv(&shape, 9000);
    let uncond = rand_qkv(&shape, 9100);
    let scale = 6.5;
    let want = guided_attention_oracle(&cond, &uncond, scale).unwrap();
    for algo in SpAlgo::ALL {
        let pu = match algo {
            SpAlgo::Ring => 1,
            _ => 2,
        };
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(pu, 2 / pu));
        let plan = ParallelPlan::build(&cluster, spec, algo).unwrap();
        let (got, _) = guided_attention_distributed(
            &plan,
            shape,
            16,
            &cond,
            &uncond,
            scale,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < TOL, "{} cfg2 on 2x2: diff {diff}", algo.name());
    }
}

#[test]
fn batch_replica_groups_are_independent_and_exact() {
    // cfg_degree=1 × batch_replicas=2: every replica group runs both
    // branches on its own carved mesh; numerics still match the oracle.
    let cluster = ClusterSpec::new(2, 2);
    let shape = AttnShape::new(1, 32, 4, 8);
    let spec = ParallelSpec::new(1, 2, SpDegrees::new(2, 1));
    let plan = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
    let cond = rand_qkv(&shape, 777);
    let uncond = rand_qkv(&shape, 888);
    let (got, _) = guided_attention_distributed(
        &plan,
        shape,
        16,
        &cond,
        &uncond,
        4.0,
        &ExecMode::HostNumeric,
    )
    .unwrap();
    let want = guided_attention_oracle(&cond, &uncond, 4.0).unwrap();
    assert!(got.max_abs_diff(&want) < TOL);
}

#[test]
fn prop_pipefusion_warmup_matches_oracle() {
    // The synchronous warm-up step of the displaced patch pipeline for
    // pp_degree ∈ {2, 4} on random shapes/meshes: every stage runs the
    // plan's SpAlgo over the full sequence, so the step must equal the
    // stacked plain-softmax oracle within the repo-wide exactness bar.
    prop::run(8, |g| {
        let pp = *g.choose(&[2usize, 4]);
        let sp = *g.choose(&[1usize, 2]);
        // one machine holding every stage, or one stage per machine
        let cluster = if g.bool() {
            ClusterSpec::new(1, pp * sp)
        } else {
            ClusterSpec::new(pp, sp)
        };
        let h = sp * g.int(1, 2);
        let d = *g.choose(&[4usize, 8]);
        let chunk = *g.choose(&[2usize, 4]);
        let patches = *g.choose(&[2usize, 4]);
        let shape = AttnShape::new(1, patches * sp * chunk, h, d);
        let algo = *g.choose(&SpAlgo::ALL);
        let pu = pick_pu(g, algo, sp, h);
        let spec = ParallelSpec::with_pp(1, pp, 1, SpDegrees::new(pu, sp / pu));
        assert!(spec.validate(&cluster).is_ok(), "{spec:?}");
        let plan = ParallelPlan::build(&cluster, spec, algo).unwrap();
        let p = PipeParams { shape, chunk, patches };

        let dims = [shape.b, shape.l, shape.h, shape.d];
        let x = Tensor::random(&dims, g.seed ^ 0xF00);
        let cb = Tensor::random(&dims, g.seed ^ 0xF11).scale(0.5);
        let xc = x.add(&cb).unwrap();
        let scale = g.f64(0.0, 4.0) as f32;
        let step = guided_pipefusion_step(
            &plan,
            &p,
            &xc,
            &x,
            scale,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guidance_combine(
            &stacked_attention_oracle(&xc, pp),
            &stacked_attention_oracle(&x, pp),
            scale,
        )
        .unwrap();
        let diff = step.eps.max_abs_diff(&want);
        assert!(
            diff < TOL,
            "{} pp{pp} sp{sp} patches{patches} warm-up: diff {diff}",
            algo.name()
        );
        assert!(step.makespan > 0.0);
    });
}

#[test]
fn prop_pipefusion_stale_kv_within_tolerance() {
    // Steady state: a short multi-step loop with one-step-stale KV for
    // pp_degree ∈ {2, 4} stays within the documented STALE_TOL of the
    // staleness-free oracle (and the warm-up-only prefix stays exact).
    prop::run(6, |g| {
        let pp = *g.choose(&[2usize, 4]);
        let cluster = ClusterSpec::new(1, pp);
        let spec = ParallelSpec::with_pp(1, pp, 1, SpDegrees::new(1, 1));
        let plan = ParallelPlan::build(&cluster, spec, SpAlgo::Ring).unwrap();
        let chunk = 4;
        let patches = *g.choose(&[2usize, 4]);
        let shape = AttnShape::new(1, patches * chunk, *g.choose(&[2usize, 4]), 4);
        let p = PipeParams { shape, chunk, patches };
        let dims = [shape.b, shape.l, shape.h, shape.d];
        let x0 = Tensor::random(&dims, g.seed ^ 0xAB);
        let cb = Tensor::random(&dims, g.seed ^ 0xAC).scale(0.5);

        // warm-up only: exact
        let (one, _) = guided_pipefusion_generate(
            &plan,
            &p,
            1,
            STALE_ETA,
            &x0,
            &cb,
            1.5,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let one_want = guided_pipefusion_oracle(pp, 1, STALE_ETA, &x0, &cb, 1.5).unwrap();
        let d1 = one.max_abs_diff(&one_want);
        assert!(d1 < TOL, "pp{pp} warm-up prefix: {d1}");

        // three steps: two of them displaced
        let (got, makespan) = guided_pipefusion_generate(
            &plan,
            &p,
            3,
            STALE_ETA,
            &x0,
            &cb,
            1.5,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guided_pipefusion_oracle(pp, 3, STALE_ETA, &x0, &cb, 1.5).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(
            diff < STALE_TOL,
            "pp{pp} patches{patches} stale loop drifted {diff} (tol {STALE_TOL})"
        );
        assert!(makespan > 0.0);
    });
}

#[test]
fn cfg2_pp2_carve_on_testbed_matches_oracle() {
    // The acceptance case, pinned: the 4x8 testbed carved cfg2 x pp2 x
    // sp8 (each guidance branch a two-stage pipeline, each stage exactly
    // one machine). Warm-up equals the stacked guided oracle; a short
    // displaced loop stays within the documented tolerance.
    let cluster = ClusterSpec::new(4, 8);
    let spec = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
    let plan = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
    let patches = 2;
    let chunk = 2;
    let shape = AttnShape::new(1, patches * 8 * chunk, 8, 4);
    let p = PipeParams { shape, chunk, patches };
    let dims = [shape.b, shape.l, shape.h, shape.d];
    let x = Tensor::random(&dims, 4242);
    let cb = Tensor::random(&dims, 4243).scale(0.5);
    let xc = x.add(&cb).unwrap();

    let step = guided_pipefusion_step(
        &plan,
        &p,
        &xc,
        &x,
        5.0,
        None,
        &ExecMode::HostNumeric,
    )
    .unwrap();
    let want = guidance_combine(
        &stacked_attention_oracle(&xc, 2),
        &stacked_attention_oracle(&x, 2),
        5.0,
    )
    .unwrap();
    let diff = step.eps.max_abs_diff(&want);
    assert!(diff < TOL, "cfg2 x pp2 on 4x8 warm-up: diff {diff}");

    let (got, _) = guided_pipefusion_generate(
        &plan,
        &p,
        3,
        STALE_ETA,
        &x,
        &cb,
        1.5,
        &ExecMode::HostNumeric,
    )
    .unwrap();
    let oracle = guided_pipefusion_oracle(2, 3, STALE_ETA, &x, &cb, 1.5).unwrap();
    let d3 = got.max_abs_diff(&oracle);
    assert!(d3 < STALE_TOL, "cfg2 x pp2 stale loop: {d3}");
}

#[test]
fn epoch_boundary_recarve_stays_oracle_exact() {
    // Dynamic re-carving's numeric contract: a pod serving one request
    // stream changes its plan *between* requests (drain + rebuild, no
    // request ever spans two carves), and every request must still match
    // the single-device oracle under whichever epoch served it. The
    // transition here is the acceptance case: a pipelined cfg2 × pp2 ×
    // sp8 carve of the 4×8 testbed re-carved to an sp-only cfg1 × U8R4
    // mesh — i.e. a pp > 1 → pp = 1 boundary — driven through the real
    // policy machinery (EpochTracker), with both epochs' ParallelPlans
    // rebuilt from their specs exactly as a live pod would.
    let cluster = ClusterSpec::new(4, 8);
    let piped = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
    let sp_only = ParallelSpec::new(1, 1, SpDegrees::new(8, 4));
    let mut tracker =
        EpochTracker::new(RecarvePolicy::Hysteresis { threshold: 0.1, window: 1 }, 0.03);

    // admission: the pod carves into the pipelined plan (epoch 0)
    let t0 = tracker.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(piped));
    assert!(!t0.recarved);
    let plan_a = tracker.carved_plan(&cluster, SpAlgo::SwiftFusion).unwrap();
    assert_eq!(plan_a.spec, piped);

    // request 1 under epoch 0: the synchronous pipeline warm-up step
    // must equal the stacked guided oracle
    let shape = AttnShape::new(1, 64, 8, 4);
    let p = PipeParams { shape, chunk: 2, patches: 2 };
    let dims = [shape.b, shape.l, shape.h, shape.d];
    let x = Tensor::random(&dims, 31_337);
    let cb = Tensor::random(&dims, 31_338).scale(0.5);
    let xc = x.add(&cb).unwrap();
    let step = guided_pipefusion_step(&plan_a, &p, &xc, &x, 5.0, None, &ExecMode::HostNumeric)
        .unwrap();
    let want_a = guidance_combine(
        &stacked_attention_oracle(&xc, 2),
        &stacked_attention_oracle(&x, 2),
        5.0,
    )
    .unwrap();
    let d_a = step.eps.max_abs_diff(&want_a);
    assert!(d_a < TOL, "epoch 0 (cfg2 x pp2) vs oracle: {d_a}");
    tracker.record_served(1);

    // traffic shifts: the chooser prefers the sp-only plan and the
    // hysteresis policy fires — drain the pod, rebuild the carve
    let t1 = tracker.on_dispatch(&PolicyCtx::at(1.0, 0.5).preferred(sp_only).gain(0.5));
    assert!(t1.recarved, "policy must fire across the boundary");
    assert_eq!(t1.setup, 0.03);
    let plan_b = tracker.carved_plan(&cluster, SpAlgo::SwiftFusion).unwrap();
    assert_eq!(plan_b.spec, sp_only);
    assert_eq!(plan_b.spec.pp_degree, 1, "pp2 -> pp1 transition");
    assert_eq!(plan_b.groups.len(), 1);

    // request 2 (same stream, new epoch): a guided layer on the rebuilt
    // 32-rank mesh must equal the guided oracle
    let cond = rand_qkv(&shape, 41_000);
    let uncond = rand_qkv(&shape, 42_000);
    let (got, _) = guided_attention_distributed(
        &plan_b,
        shape,
        2,
        &cond,
        &uncond,
        6.5,
        &ExecMode::HostNumeric,
    )
    .unwrap();
    let want_b = guided_attention_oracle(&cond, &uncond, 6.5).unwrap();
    let d_b = got.max_abs_diff(&want_b);
    assert!(d_b < TOL, "epoch 1 (sp-only) vs oracle: {d_b}");
    tracker.record_served(1);

    // the epoch log shows one request per carve and disjoint epochs —
    // no request spanned the boundary
    let epochs = tracker.epochs();
    assert_eq!(epochs.len(), 2);
    assert_eq!((epochs[0].served, epochs[1].served), (1, 1));
    assert!(epochs[1].started_at > epochs[0].started_at);
    assert_eq!(epochs[0].plan, Some(piped));
    assert_eq!(epochs[1].plan, Some(sp_only));
}

#[test]
fn partial_epoch_boundary_recarve_stays_oracle_exact() {
    // Group-granular re-carving's numeric contract: a *partial* epoch
    // boundary re-carves only a machine subset of the pod while a
    // sibling group keeps serving uninterrupted — and every request,
    // on either side of the boundary and on either generation, must
    // still match the single-device oracle. Here a 4×2 pod starts
    // carved cfg2 × pp2 × rep2 (four 1-machine branch groups); the
    // traffic shifts while the replica-0 branch pair (machines 0–1) is
    // busy, so machines 2–3 re-carve from their cfg2 × pp2 slice to an
    // sp-only U2R2 mesh — driven through the real policy machinery
    // (EpochTracker::{on_dispatch, split}) with both generations'
    // ParallelPlans carved as pod-absolute machine subsets
    // (ParallelPlan::build_subset), exactly as a live split pod holds
    // them.
    let cluster = ClusterSpec::new(4, 2);
    let full = ParallelSpec::with_pp(2, 2, 2, SpDegrees::new(1, 1));
    assert!(full.validate(&cluster).is_ok());
    let narrowed = full
        .narrowed_to_machines(cluster.gpus_per_machine)
        .expect("rep2 narrows to the busy rep-0 pair");
    assert_eq!(narrowed.batch_replicas, 1);
    assert_eq!(narrowed.total_ranks(), 4, "busy generation = machines 0-1");
    let side_spec = ParallelSpec::new(1, 1, SpDegrees::new(2, 2));

    let policy = RecarvePolicy::Partial { threshold: 0.1, window: 1 };
    let mut tracker = EpochTracker::new(policy, 0.05);
    let t0 = tracker.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(full));
    assert!(!t0.recarved && !t0.split_pending);

    // the busy generation: the rep-0 branch pair as a machine subset at
    // base machine 0, running the displaced patch pipeline (cfg2 x pp2)
    let plan_main =
        ParallelPlan::build_subset(&cluster, narrowed, SpAlgo::SwiftFusion, 0).unwrap();
    assert_eq!(plan_main.base_rank, 0);
    let shape = AttnShape::new(1, 8, 2, 4);
    let p = PipeParams { shape, chunk: 2, patches: 2 };
    let dims = [shape.b, shape.l, shape.h, shape.d];

    // request 1 under epoch 0 (pipelined warm-up step = stacked oracle)
    let x1 = Tensor::random(&dims, 61_001);
    let cb = Tensor::random(&dims, 61_002).scale(0.5);
    let xc1 = x1.add(&cb).unwrap();
    let mode = ExecMode::HostNumeric;
    let step1 = guided_pipefusion_step(&plan_main, &p, &xc1, &x1, 4.0, None, &mode).unwrap();
    let want1 = guidance_combine(
        &stacked_attention_oracle(&xc1, 2),
        &stacked_attention_oracle(&x1, 2),
        4.0,
    )
    .unwrap();
    let d1 = step1.eps.max_abs_diff(&want1);
    assert!(d1 < TOL, "request 1 (cfg2 x pp2, machines 0-1): diff {d1}");
    tracker.record_served(1);

    // traffic shifts while the pod is busy (free_at 5 > ready 1): the
    // Partial policy asks for a split instead of a pod-wide drain
    let preferred = ParallelSpec::new(1, 1, SpDegrees::new(2, 4));
    assert!(preferred.validate(&cluster).is_ok());
    let t1 = tracker.on_dispatch(&PolicyCtx::at(1.0, 5.0).preferred(preferred).gain(0.9));
    assert!(t1.split_pending, "busy pod must request a split");
    assert!(!t1.recarved);
    let pr = tracker.split(1.0, Some(narrowed), Some(side_spec), 2, 2);
    assert_eq!(pr.setup, 0.05);
    assert_eq!((pr.base_machine, pr.machines), (2, 2));

    // the idle machines 2-3 re-carve cfg2 x pp2 -> sp-only: a
    // pod-absolute subset plan whose ranks start at 4
    let plan_side =
        ParallelPlan::build_subset(&cluster, side_spec, SpAlgo::SwiftFusion, 2).unwrap();
    assert_eq!(plan_side.base_rank, 4);
    assert_eq!(plan_side.groups[0].base(), 4);
    assert!(!plan_side.contains(0) && plan_side.contains(7));

    // request 2 on the re-carved side generation: guided layer on the
    // 4-rank U2R2 subset mesh vs the guided oracle
    let cond = rand_qkv(&shape, 62_001);
    let uncond = rand_qkv(&shape, 63_001);
    let (got2, makespan2) = guided_attention_distributed(
        &plan_side,
        shape,
        2,
        &cond,
        &uncond,
        6.5,
        &ExecMode::HostNumeric,
    )
    .unwrap();
    let want2 = guided_attention_oracle(&cond, &uncond, 6.5).unwrap();
    let d2 = got2.max_abs_diff(&want2);
    assert!(d2 < TOL, "request 2 (sp-only side, machines 2-3): diff {d2}");
    assert!(makespan2 > 0.0);
    tracker.record_side_served(1);

    // request 3 back on the *sibling* generation, which never stopped:
    // same carve, same exactness — the split did not touch its meshes
    let x3 = Tensor::random(&dims, 64_001);
    let xc3 = x3.add(&cb).unwrap();
    let step3 = guided_pipefusion_step(&plan_main, &p, &xc3, &x3, 4.0, None, &mode).unwrap();
    let want3 = guidance_combine(
        &stacked_attention_oracle(&xc3, 2),
        &stacked_attention_oracle(&x3, 2),
        4.0,
    )
    .unwrap();
    let d3 = step3.eps.max_abs_diff(&want3);
    assert!(d3 < TOL, "request 3 (sibling uninterrupted): diff {d3}");
    tracker.record_served(1);

    // the epoch machinery attributed every request to its generation
    assert!(tracker.is_split());
    assert_eq!(tracker.partial_splits(), 1);
    assert_eq!(tracker.recarve_count(), 0, "no pod-wide transition happened");
    assert_eq!(tracker.drain_time(), 0.0, "the split drained nothing");
    let epochs = tracker.epochs();
    assert_eq!(epochs.len(), 2, "admission + narrowed main epoch");
    assert_eq!(epochs[0].served + epochs[1].served, 2);
    let group = tracker.group_epochs();
    assert_eq!(group.len(), 1);
    assert_eq!(group[0].plan, Some(side_spec));
    assert_eq!(group[0].served, 1);
    assert_eq!(group[0].merged_at, None);
}

#[test]
fn compressed_inter_hops_stay_within_derived_tolerance() {
    // The compression knob's numeric contract: with inter_compress = 0.5
    // every inter-machine hop quantizes its real payload to the 16-bit
    // wire grid, and the full multi-machine SwiftFusion schedule must
    // still match the plain-softmax oracle within the COMPRESS_TOL
    // derived from that grid. Two supporting assertions prove the
    // compressed path actually fired (an accidentally-inert knob would
    // pass the tolerance check trivially): the compressed outputs differ
    // from the uncompressed run's, and the measured inter wire bytes are
    // exactly half the uncompressed run's — the same multiplier the
    // timing model and the analysis closed form charge.
    let plain_cluster = ClusterSpec::new(2, 2);
    let mut comp_cluster = plain_cluster.clone();
    comp_cluster.net.inter_compress = 0.5;

    let p = plain_cluster.total_gpus();
    let shape = AttnShape::new(1, 64, 4, 8);
    let chunk = 8;
    let ls = shape.l / p;
    let (q, k, v) = rand_qkv(&shape, 0x51AB);
    let oracle = host::attention_oracle(&q, &k, &v);

    let run_on = |cluster: &ClusterSpec| {
        let params = SpParams {
            shape,
            chunk,
            mesh: SpAlgo::SwiftFusion.mesh(cluster, SpDegrees::new(2, 2)),
        };
        let world = CommWorld::new(cluster.clone());
        let run = run_in_world(&world, &ExecMode::HostNumeric, |ctx| {
            let r = ctx.rank;
            let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
            let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
            let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
            SpAlgo::SwiftFusion.run(ctx, &params, qs, ks, vs).into_tensor()
        });
        (run.outputs, world.traffic_totals())
    };
    let (plain_out, plain_traffic) = run_on(&plain_cluster);
    let (comp_out, comp_traffic) = run_on(&comp_cluster);

    let mut vs_plain = 0f32;
    for (rank, got) in comp_out.iter().enumerate() {
        let want = oracle.slice(1, rank * ls, (rank + 1) * ls).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(
            diff < COMPRESS_TOL,
            "compressed rank {rank} vs oracle: {diff} (tol {COMPRESS_TOL})"
        );
        vs_plain = vs_plain.max(got.max_abs_diff(&plain_out[rank]));
    }
    assert!(
        vs_plain > 0.0,
        "compressed run bit-identical to uncompressed — the quantizer never fired"
    );
    assert!(
        plain_traffic.inter_in > 0.0,
        "schedule must cross machines for the knob to matter"
    );
    let rel = (comp_traffic.inter_in - 0.5 * plain_traffic.inter_in).abs()
        / plain_traffic.inter_in;
    assert!(
        rel < 1e-12,
        "inter wire bytes: compressed {} vs 0.5 x plain {}",
        comp_traffic.inter_in,
        plain_traffic.inter_in
    );
    assert_eq!(
        comp_traffic.intra_in, plain_traffic.intra_in,
        "intra-machine hops are never compressed"
    );
}

#[test]
fn prop_displaced_patch_warmup_exact_and_stale_generation_bounded() {
    // The DistriFusion-style quality mode on random shapes and meshes:
    // the synchronous warm-up step is oracle-exact (same contract as
    // pipefusion's warm-up), and a short generation serving remote
    // patches one-step stale stays within the documented STALE_TOL of
    // the staleness-free pp=1 oracle.
    prop::run(6, |g| {
        let (n, m) = *g.choose(&[(1, 2), (2, 1), (1, 4), (2, 2), (4, 1)]);
        let cluster = ClusterSpec::new(n, m);
        let sp = n * m;
        let chunk = *g.choose(&[2usize, 4]);
        let shape =
            AttnShape::new(1, sp * chunk * g.int(1, 2), *g.choose(&[2usize, 4]), 4);
        let spec = ParallelSpec::new(1, 1, SpDegrees::new(1, sp));
        assert!(spec.validate(&cluster).is_ok(), "{spec:?} on {n}x{m}");
        let plan = ParallelPlan::build(&cluster, spec, SpAlgo::DisplacedPatch).unwrap();
        let p = DispParams { shape, chunk };
        let dims = [shape.b, shape.l, shape.h, shape.d];
        let x = Tensor::random(&dims, g.seed ^ 0xD15);
        let cb = Tensor::random(&dims, g.seed ^ 0xD16).scale(0.5);
        let xc = x.add(&cb).unwrap();
        let scale = g.f64(0.0, 4.0) as f32;

        // warm-up (no caches): synchronous schedule, oracle-exact
        let step =
            guided_displaced_step(&plan, &p, &xc, &x, scale, None, &ExecMode::HostNumeric)
                .unwrap();
        let want = guidance_combine(
            &stacked_attention_oracle(&xc, 1),
            &stacked_attention_oracle(&x, 1),
            scale,
        )
        .unwrap();
        let d0 = step.eps.max_abs_diff(&want);
        assert!(d0 < TOL, "sp{sp} on {n}x{m} displaced warm-up: diff {d0}");

        // three steps (two of them displaced): bounded stale drift
        let (got, makespan) = guided_displaced_generate(
            &plan,
            &p,
            3,
            STALE_ETA,
            &x,
            &cb,
            scale,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let oracle = guided_pipefusion_oracle(1, 3, STALE_ETA, &x, &cb, scale).unwrap();
        let diff = got.max_abs_diff(&oracle);
        assert!(
            diff < STALE_TOL,
            "sp{sp} on {n}x{m} displaced loop drifted {diff} (tol {STALE_TOL})"
        );
        assert!(makespan > 0.0);
    });
}

#[test]
fn prop_fastattn_matches_windowed_oracle_and_full_window_is_exact() {
    // The FastAttn quality mode on random shapes and meshes. Sharp
    // check: the distributed path equals the per-tile windowed
    // plain-softmax oracle (same clamped window arithmetic) at the
    // repo-wide exactness bar, and keep_ratio = 1.0 degenerates to the
    // exact algorithm. Bounding check: outputs stay inside the convex
    // hull of V and the approximation drift stays below its
    // mass-transfer ceiling (FASTATTN_TOL) while actually pruning.
    prop::run(6, |g| {
        let (n, m) = *g.choose(&[(1, 1), (1, 2), (2, 1), (2, 2)]);
        let cluster = ClusterSpec::new(n, m);
        let p_ranks = n * m;
        let chunk = *g.choose(&[2usize, 4]);
        let shape = AttnShape::new(
            1,
            p_ranks * g.int(2, 4) * chunk,
            *g.choose(&[2usize, 4]),
            4,
        );
        let (q, k, v) = rand_qkv(&shape, g.seed ^ 0xFA57);
        let ls = shape.l / p_ranks;
        let params = SpParams {
            shape,
            chunk,
            mesh: SpAlgo::DisplacedPatch.mesh(&cluster, SpDegrees::new(1, p_ranks)),
        };
        let run_keep = |keep_ratio: f64| {
            run_cluster(&cluster, &ExecMode::HostNumeric, |ctx| {
                let r = ctx.rank;
                let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
                let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
                let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
                fastattn_attention(ctx, &params, qs, ks, vs, keep_ratio).into_tensor()
            })
            .outputs
        };

        // keep_ratio = 1.0: the full window is the exact algorithm
        let full_oracle = host::attention_oracle(&q, &k, &v);
        for (rank, got) in run_keep(1.0).iter().enumerate() {
            let want = full_oracle.slice(1, rank * ls, (rank + 1) * ls).unwrap();
            let d = got.max_abs_diff(&want);
            assert!(d < TOL, "fastattn keep=1.0 rank {rank}: {d}");
        }

        // keep_ratio = 0.5: per-tile windowed oracle, same window math
        let nt = shape.l / chunk;
        let keep = ((FASTATTN_KEEP * nt as f64).ceil() as usize).clamp(1, nt);
        assert!(keep < nt, "shapes above guarantee a real pruning window");
        let mut drift = 0f32;
        for (rank, got) in run_keep(FASTATTN_KEEP).iter().enumerate() {
            let tiles: Vec<Tensor> = (0..ls / chunk)
                .map(|i| {
                    let gi = rank * (ls / chunk) + i;
                    let start = gi.saturating_sub(keep / 2).min(nt - keep);
                    let qt = q
                        .slice(1, rank * ls + i * chunk, rank * ls + (i + 1) * chunk)
                        .unwrap();
                    let kw = k.slice(1, start * chunk, (start + keep) * chunk).unwrap();
                    let vw = v.slice(1, start * chunk, (start + keep) * chunk).unwrap();
                    host::attention_oracle(&qt, &kw, &vw)
                })
                .collect();
            let refs: Vec<&Tensor> = tiles.iter().collect();
            let want = Tensor::concat(&refs, 1).unwrap();
            let d = got.max_abs_diff(&want);
            assert!(d < TOL, "fastattn keep=0.5 rank {rank} vs windowed oracle: {d}");
            // still a convex combination of V rows in (-1, 1)
            assert!(
                got.data().iter().all(|x| x.abs() <= 1.0 + TOL),
                "windowed output escaped the convex hull of V"
            );
            let full_want = full_oracle.slice(1, rank * ls, (rank + 1) * ls).unwrap();
            let approx = got.max_abs_diff(&full_want);
            assert!(
                approx < FASTATTN_TOL,
                "fastattn keep=0.5 rank {rank} drift {approx} (ceiling {FASTATTN_TOL})"
            );
            drift = drift.max(approx);
        }
        assert!(
            drift > 0.0,
            "keep=0.5 bit-identical to the exact output — the window never pruned"
        );
    });
}

#[test]
fn displaced_with_compressed_inter_hops_stays_within_composed_tolerance() {
    // Quality-mode composition: displaced patch parallelism across two
    // machines *with* inter_compress = 0.5 — every cross-machine patch
    // allgather quantizes to the 16-bit wire grid on top of the
    // one-step-stale drift. The two error sources are independent and
    // additive, so the composed run must stay within STALE_TOL +
    // COMPRESS_TOL of the staleness-free uncompressed oracle.
    let plain = ClusterSpec::new(2, 1);
    let mut comp = plain.clone();
    comp.net.inter_compress = 0.5;
    let spec = ParallelSpec::new(1, 1, SpDegrees::new(1, 2));
    let shape = AttnShape::new(1, 16, 2, 8);
    let p = DispParams { shape, chunk: 4 };
    let dims = [shape.b, shape.l, shape.h, shape.d];
    let x0 = Tensor::random(&dims, 0xD1FF);
    let cb = Tensor::random(&dims, 0xD200).scale(0.5);

    let run_on = |cluster: &ClusterSpec| {
        let plan = ParallelPlan::build(cluster, spec, SpAlgo::DisplacedPatch).unwrap();
        guided_displaced_generate(
            &plan,
            &p,
            3,
            STALE_ETA,
            &x0,
            &cb,
            1.5,
            &ExecMode::HostNumeric,
        )
        .unwrap()
        .0
    };
    let plain_out = run_on(&plain);
    let comp_out = run_on(&comp);
    let oracle = guided_pipefusion_oracle(1, 3, STALE_ETA, &x0, &cb, 1.5).unwrap();

    let d_comp = comp_out.max_abs_diff(&oracle);
    assert!(
        d_comp < STALE_TOL + COMPRESS_TOL,
        "displaced + compression drifted {d_comp} (tol {})",
        STALE_TOL + COMPRESS_TOL
    );
    // the quantizer actually fired on the inter hops...
    let vs_plain = comp_out.max_abs_diff(&plain_out);
    assert!(
        vs_plain > 0.0,
        "compressed displaced run bit-identical to uncompressed — \
         the quantizer never fired"
    );
    // ...and added at most its own documented budget on top of staleness
    assert!(
        vs_plain < COMPRESS_TOL,
        "compression added {vs_plain} on top of the stale drift \
         (budget {COMPRESS_TOL})"
    );
}

#[test]
fn prop_host_mode_agrees_across_algorithms() {
    // Cross-algorithm agreement without any oracle: all six algorithms
    // are the same mathematical function, so pairwise outputs must agree
    // even on shapes where we never computed the plain-softmax reference.
    prop::run(6, |g| {
        let cluster = ClusterSpec::new(2, 2);
        let h = *g.choose(&[4usize, 8]);
        let chunk = *g.choose(&[4usize, 8]);
        let shape = AttnShape::new(1, 4 * chunk, h, *g.choose(&[4usize, 8]));
        let (q, k, v) = rand_qkv(&shape, g.seed ^ 0xBEEF);
        let ls = shape.l / 4;
        let mut first: Option<(String, Vec<Tensor>)> = None;
        for algo in SpAlgo::ALL {
            let pu = pick_pu(g, algo, 4, h);
            let params = SpParams {
                shape,
                chunk,
                mesh: algo.mesh(&cluster, SpDegrees::new(pu, 4 / pu)),
            };
            let run = run_cluster(&cluster, &ExecMode::HostNumeric, |ctx| {
                let r = ctx.rank;
                let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
                let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
                let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
                algo.run(ctx, &params, qs, ks, vs).into_tensor()
            });
            match &first {
                None => first = Some((algo.name().to_string(), run.outputs)),
                Some((base_name, base)) => {
                    for (rank, (a, b)) in base.iter().zip(&run.outputs).enumerate() {
                        let diff = a.max_abs_diff(b);
                        assert!(
                            diff < TOL,
                            "{base_name} vs {} rank {rank}: {diff}",
                            algo.name()
                        );
                    }
                }
            }
        }
    });
}
