//! Compile-time pins of the coordinator's public API surface.
//!
//! Each binding below coerces a public function/method to an explicit
//! function-pointer type: if a signature drifts (argument added, return
//! type changed, trait method moved), this test stops *compiling* —
//! turning silent API breakage into a reviewed, deliberate change. The
//! trait-bound assertions pin the `CostModel + Planner = ServiceModel`
//! composition (including the blanket impl for plan-agnostic models)
//! and object safety of every scheduler trait.

use std::sync::Arc;

use swiftfusion::analysis::{EwmaForecaster, Forecaster};
use swiftfusion::cluster::recarve::{
    EpochTracker, GroupEpoch, PartialRecarve, PolicyCtx, RecarvePolicy, Transition,
};
use swiftfusion::config::{ClusterSpec, ParallelSpec, ParallelSpecError, QualityMode};
use swiftfusion::coordinator::batcher::{Batch, BatchPolicy};
use swiftfusion::coordinator::engine::{serve, ServeReport, SimService};
use swiftfusion::coordinator::metrics::Completion;
use swiftfusion::coordinator::router::{DispatchOutcome, RebalanceEvent, Router};
use swiftfusion::coordinator::session::{
    dispatch_policy_from_name, DispatchPolicy, EarliestFinish, FleetModel, ForecastCfg,
    LeastLoaded, QualityCfg, RebalanceCfg, RebalancePolicy, RecarveCfg, ServeConfig, ServeSession,
    ServeState, SimFleet, StageCfg, DEFAULT_FORECAST_WINDOW,
};
use swiftfusion::coordinator::{CostModel, Planner, ServiceModel};
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::{Request, Workload};

/// The legacy entry point: its exact signature is frozen — it is the
/// compatibility shim the redesign promised to keep.
const _SERVE: fn(&mut Router, BatchPolicy, Vec<Request>, &dyn ServiceModel) -> ServeReport =
    serve;

/// Router surface.
const _DISPATCH: fn(&mut Router, usize, f64, f64) -> DispatchOutcome = Router::dispatch;
const _REBALANCE: fn(&mut Router, usize, usize, f64) -> RebalanceEvent =
    Router::rebalance_machine;
const _PICK: fn(&Router) -> usize = Router::pick;

/// SimService constructors.
const _SIM_NEW: fn(ClusterSpec, SpAlgo) -> SimService = SimService::new;
const _SIM_AUTO: fn(ClusterSpec, SpAlgo) -> SimService = SimService::auto_plan;
const _SIM_PLAN: fn(ClusterSpec, SpAlgo, ParallelSpec) -> Result<SimService, ParallelSpecError> =
    SimService::with_plan;

#[test]
fn session_api_signatures_are_pinned() {
    // ServeSession construction + run (instantiated at a concrete
    // lifetime so the fn items coerce to pointers).
    let new: fn(ServeConfig, &'static dyn ServiceModel) -> ServeSession<'static> =
        ServeSession::new;
    let with_fleet: fn(ServeConfig, &'static dyn FleetModel) -> ServeSession<'static> =
        ServeSession::with_fleet;
    let run: fn(ServeSession<'static>, &mut Router, Vec<Request>) -> ServeReport =
        ServeSession::run;
    let _ = (new, with_fleet, run);

    // ServeConfig builder methods.
    let b: fn(ServeConfig, BatchPolicy) -> ServeConfig = ServeConfig::batch;
    let p: fn(ServeConfig, usize) -> ServeConfig = ServeConfig::patches;
    let d: fn(ServeConfig, Arc<dyn DispatchPolicy>) -> ServeConfig = ServeConfig::dispatch;
    let c: fn(ServeConfig, bool) -> ServeConfig = ServeConfig::co_batch;
    let r: fn(ServeConfig, RebalancePolicy) -> ServeConfig = ServeConfig::rebalance;
    let s: fn(&ServeConfig) -> String = ServeConfig::summary;
    let m: fn(&ServeConfig, ClusterSpec, SpAlgo) -> Result<SimService, ParallelSpecError> =
        ServeConfig::sim_service;
    let _ = (b, p, d, c, r, s, m);

    let parse: fn(&str) -> Option<Arc<dyn DispatchPolicy>> = dispatch_policy_from_name;
    let _ = parse;

    // Sub-struct builders keep their pre-redesign names and signatures
    // (the back-compat promise of the config regrouping), plus the new
    // forecast knob and the preset constructor.
    let rc: fn(ServeConfig, RecarvePolicy) -> ServeConfig = ServeConfig::recarve;
    let rs: fn(ServeConfig, f64) -> ServeConfig = ServeConfig::recarve_setup;
    let q: fn(ServeConfig, QualityMode) -> ServeConfig = ServeConfig::quality;
    let qf: fn(ServeConfig, f64) -> ServeConfig = ServeConfig::quality_floor;
    let fw: fn(ServeConfig, f64) -> ServeConfig = ServeConfig::forecast_window;
    let preset: fn(&str) -> ServeConfig = ServeConfig::preset;
    let _ = (rc, rs, q, qf, fw, preset);
}

/// The typed config sub-structs: constructing each field-by-field
/// pins its shape, and the defaults pin the knob-off posture (every
/// `None`/`Never` default keeps reports byte-identical to the
/// pre-regrouping output).
#[test]
fn config_substruct_shapes_are_pinned() {
    let rc = RecarveCfg { policy: Some(RecarvePolicy::Free), setup: Some(0.5) };
    assert!(rc.policy.is_some() && rc.setup.is_some());
    assert!(RecarveCfg::default().policy.is_none());

    let rb = RebalanceCfg { policy: RebalancePolicy::Never };
    assert_eq!(rb.policy, RebalanceCfg::default().policy);

    let q = QualityCfg { floor: Some(0.9), forced: Some(QualityMode::Full) };
    assert!(q.floor.is_some() && q.forced.is_some());
    assert!(QualityCfg::default().floor.is_none());

    let st = StageCfg { policy: None };
    assert!(st.policy.is_none() && StageCfg::default().policy.is_none());

    let f = ForecastCfg { window: 4.0 };
    assert!(f.window < DEFAULT_FORECAST_WINDOW);
    assert_eq!(ForecastCfg::default().window, DEFAULT_FORECAST_WINDOW);

    // The default config keeps every knob off, and its summary line is
    // the same one the pre-regrouping config printed.
    let config = ServeConfig::new();
    assert!(config.recarve.policy.is_none() && config.recarve.setup.is_none());
    assert_eq!(config.rebalance.policy, RebalancePolicy::Never);
    assert!(config.quality.floor.is_none() && config.quality.forced.is_none());
    assert!(config.stages.policy.is_none());
    assert!(config.forecast.is_none());
    assert!(!config.summary().contains("forecast="));
}

/// The three presets: each is an ordinary config (explicit builder
/// calls still override it), and only `latency` turns the forecaster
/// on.
#[test]
fn presets_are_pinned() {
    let t = ServeConfig::preset("throughput");
    assert!(t.co_batch && t.forecast.is_none());
    assert!(matches!(t.recarve.policy, Some(RecarvePolicy::Partial { .. })));
    assert!(matches!(t.rebalance.policy, RebalancePolicy::Gain { .. }));

    let l = ServeConfig::preset("latency");
    assert!(matches!(l.recarve.policy, Some(RecarvePolicy::Forecast { .. })));
    assert_eq!(l.forecast.map(|f| f.window), Some(DEFAULT_FORECAST_WINDOW));
    assert_eq!(l.batch.max_batch, 1);

    let q = ServeConfig::preset("quality");
    assert_eq!(q.quality.forced, Some(QualityMode::Full));

    // presets compose with the builder like any other base config
    let over = ServeConfig::preset("latency").forecast_window(2.0);
    assert_eq!(over.forecast.map(|f| f.window), Some(2.0));
}

/// The shared policy-decision view: field-by-field construction pins
/// the shape; the builder chain pins the chainable setters.
#[test]
fn policy_ctx_shape_is_pinned() {
    let full = PolicyCtx {
        ready: 1.0,
        free_at: 0.5,
        preferred: None,
        gain: Some(0.2),
        forecast_share: Some(0.8),
        backlog: 3,
    };
    let built = PolicyCtx::at(1.0, 0.5).gain(0.2).forecast_share(0.8).backlog(3);
    assert_eq!(full, built);
    assert_eq!(PolicyCtx::at(0.0, 0.0).preferred(None).preferred, None);

    // EpochTracker's decision entry point takes the view by reference.
    let on_dispatch: fn(&mut EpochTracker, &PolicyCtx) -> Transition = EpochTracker::on_dispatch;
    let _ = on_dispatch;
}

/// The split traits compose back into `ServiceModel` via the blanket
/// impl — for concrete models, trait objects, and plan-agnostic models
/// that only implement `CostModel` plus an empty `Planner`.
fn is_service_model<T: ServiceModel + ?Sized>() {}
fn is_dispatch_policy<T: DispatchPolicy + ?Sized>() {}
fn is_fleet_model<T: FleetModel>() {}
fn is_forecaster<T: Forecaster + ?Sized>() {}

/// `DispatchPolicy::pick` routes its decision inputs through the
/// shared [`PolicyCtx`] view; calling it through the trait object pins
/// both the new signature and object safety.
fn pin_dispatch_policy(
    p: &dyn DispatchPolicy,
    router: &Router,
    batch: &Batch,
    ctx: &PolicyCtx,
) -> usize {
    p.pick(router, batch, ctx, &|_pod, b| b.size() as f64)
}

/// [`Forecaster`] stays object-safe (the session stores a
/// `Box<dyn Forecaster>`): observe, predict, and name through the
/// object type.
fn pin_forecaster(f: &mut dyn Forecaster) -> (f64, &'static str) {
    f.observe("flux-3072", 1.0);
    (f.share("flux-3072", 2.0), f.name())
}

#[test]
fn trait_composition_is_pinned() {
    is_service_model::<SimService>();
    is_service_model::<dyn ServiceModel>();
    is_dispatch_policy::<LeastLoaded>();
    is_dispatch_policy::<EarliestFinish>();
    is_dispatch_policy::<dyn DispatchPolicy>();
    is_fleet_model::<SimFleet>();
    is_forecaster::<EwmaForecaster>();
    is_forecaster::<dyn Forecaster>();

    let mut ewma: Box<dyn Forecaster> = Box::new(EwmaForecaster::new(DEFAULT_FORECAST_WINDOW));
    let (share, name) = pin_forecaster(ewma.as_mut());
    assert!((0.0..=1.0).contains(&share) && share > 0.0);
    assert_eq!(name, "ewma");

    let router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
    let batch = Batch {
        requests: vec![Request { id: 0, workload: Workload::flux_3072(), arrival: 0.0, seed: 0 }],
    };
    let pod = pin_dispatch_policy(&EarliestFinish, &router, &batch, &PolicyCtx::at(0.0, 0.0));
    assert!(pod < 2);

    struct OnlyCost;
    impl CostModel for OnlyCost {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            batch as f64
        }
    }
    impl Planner for OnlyCost {}
    is_service_model::<OnlyCost>();
}

/// Method signatures of the two trait halves, pinned through their
/// object types (this also proves both traits stay object-safe).
fn pin_cost_model(m: &dyn CostModel, w: &Workload, carve: Option<&ParallelSpec>) -> (f64, f64) {
    (m.service_time(w, 2), m.service_time_under(w, 2, carve))
}

#[allow(clippy::type_complexity)]
fn pin_planner(
    p: &dyn Planner,
    w: &Workload,
    from: &ParallelSpec,
) -> (Result<(), String>, Option<String>, Option<ParallelSpec>, Option<f64>) {
    (p.admit(w), p.plan_label(w), p.plan_spec(w), p.recarve_gain(w, from))
}

/// The subset-planning half of [`Planner`] (group-granular re-carving):
/// footprint-sized plan resolution and the split-gain prediction.
#[allow(clippy::type_complexity)]
fn pin_subset_planner(
    p: &dyn Planner,
    w: &Workload,
    from: &ParallelSpec,
    machines: usize,
) -> (Option<ParallelSpec>, Option<f64>) {
    (p.plan_spec_on(w, machines), p.partial_recarve_gain(w, from, machines))
}

#[test]
fn trait_method_signatures_are_pinned() {
    let svc = SimService::auto_plan(ClusterSpec::new(2, 2), SpAlgo::SwiftFusion);
    let w = Workload::flux_3072();
    let spec = ParallelSpec::single(&ClusterSpec::new(2, 2), w.shape.h);
    let (t, t_under) = pin_cost_model(&svc, &w, Some(&spec));
    assert!(t.is_finite() && t > 0.0);
    assert!(t_under > 0.0 || t_under.is_infinite());
    let (admit, label, plan, gain) = pin_planner(&svc, &w, &spec);
    assert!(admit.is_ok());
    assert!(label.is_some() && plan.is_some());
    let _ = gain;
    // subset planning: an auto-planning SimService sizes a carve to a
    // 1-machine subset of its 2-machine pod and predicts the split gain
    let (sub, sub_gain) = pin_subset_planner(&svc, &w, &spec, 1);
    assert!(sub.is_some_and(|s| s.total_ranks() == 2));
    assert!(sub_gain.is_some());
    // plan-agnostic models keep the do-not-plan defaults
    struct NoPlan;
    impl CostModel for NoPlan {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            batch as f64
        }
    }
    impl Planner for NoPlan {}
    let (sub, sub_gain) = pin_subset_planner(&NoPlan, &w, &spec, 1);
    assert!(sub.is_none() && sub_gain.is_none());
}

/// Public data-shape pins: constructing these structs field-by-field
/// fails to compile if a field is renamed, retyped, or removed.
#[test]
fn report_and_event_shapes_are_pinned() {
    let out = DispatchOutcome { start: 1.0, done: 2.0 };
    assert!(out.done >= out.start);

    let c = Completion { id: 7, workload: "flux-3072", arrival: 0.5, done: 2.5, pod: 0 };
    assert_eq!(c.latency(), 2.0);

    let ev = RebalanceEvent {
        at: 3.0,
        from_pod: 1,
        to_pod: 0,
        from_machines: 1,
        to_machines: 3,
    };
    assert_eq!(ev.from_machines + ev.to_machines, 4);

    let state = ServeState::default();
    let _: &Vec<(u64, f64, f64)> = &state.completions;
    let _: &Vec<(u64, String)> = &state.rejected;
    let _: &Vec<RebalanceEvent> = &state.rebalances;
    assert_eq!(state.co_batched, 0);
    assert_eq!(state.co_batched_cross, 0);

    // group-granular re-carving shapes
    let ge = GroupEpoch {
        index: 0,
        base_machine: 1,
        machines: 3,
        plan: None,
        started_at: 2.0,
        served: 4,
        merged_at: Some(9.0),
    };
    assert_eq!(ge.label(), "single-mesh");
    let pr = PartialRecarve {
        narrowed: None,
        side: None,
        base_machine: 1,
        machines: 3,
        setup: 0.05,
    };
    assert_eq!(pr.base_machine + pr.machines, 4);

    let batch = Batch {
        requests: vec![Request {
            id: 0,
            workload: Workload::flux_3072(),
            arrival: 0.0,
            seed: 0,
        }],
    };
    assert_eq!(batch.size(), 1);
}
