//! Compile-time pins of the coordinator's public API surface.
//!
//! Each binding below coerces a public function/method to an explicit
//! function-pointer type: if a signature drifts (argument added, return
//! type changed, trait method moved), this test stops *compiling* —
//! turning silent API breakage into a reviewed, deliberate change. The
//! trait-bound assertions pin the `CostModel + Planner = ServiceModel`
//! composition (including the blanket impl for plan-agnostic models)
//! and object safety of every scheduler trait.

use std::sync::Arc;

use swiftfusion::cluster::recarve::{GroupEpoch, PartialRecarve};
use swiftfusion::config::{ClusterSpec, ParallelSpec, ParallelSpecError};
use swiftfusion::coordinator::batcher::{Batch, BatchPolicy};
use swiftfusion::coordinator::engine::{serve, ServeReport, SimService};
use swiftfusion::coordinator::metrics::Completion;
use swiftfusion::coordinator::router::{DispatchOutcome, RebalanceEvent, Router};
use swiftfusion::coordinator::session::{
    dispatch_policy_from_name, DispatchPolicy, EarliestFinish, FleetModel, LeastLoaded,
    RebalancePolicy, ServeConfig, ServeSession, ServeState, SimFleet,
};
use swiftfusion::coordinator::{CostModel, Planner, ServiceModel};
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::{Request, Workload};

/// The legacy entry point: its exact signature is frozen — it is the
/// compatibility shim the redesign promised to keep.
const _SERVE: fn(&mut Router, BatchPolicy, Vec<Request>, &dyn ServiceModel) -> ServeReport =
    serve;

/// Router surface.
const _DISPATCH: fn(&mut Router, usize, f64, f64) -> DispatchOutcome = Router::dispatch;
const _REBALANCE: fn(&mut Router, usize, usize, f64) -> RebalanceEvent =
    Router::rebalance_machine;
const _PICK: fn(&Router) -> usize = Router::pick;

/// SimService constructors.
const _SIM_NEW: fn(ClusterSpec, SpAlgo) -> SimService = SimService::new;
const _SIM_AUTO: fn(ClusterSpec, SpAlgo) -> SimService = SimService::auto_plan;
const _SIM_PLAN: fn(ClusterSpec, SpAlgo, ParallelSpec) -> Result<SimService, ParallelSpecError> =
    SimService::with_plan;

#[test]
fn session_api_signatures_are_pinned() {
    // ServeSession construction + run (instantiated at a concrete
    // lifetime so the fn items coerce to pointers).
    let new: fn(ServeConfig, &'static dyn ServiceModel) -> ServeSession<'static> =
        ServeSession::new;
    let with_fleet: fn(ServeConfig, &'static dyn FleetModel) -> ServeSession<'static> =
        ServeSession::with_fleet;
    let run: fn(ServeSession<'static>, &mut Router, Vec<Request>) -> ServeReport =
        ServeSession::run;
    let _ = (new, with_fleet, run);

    // ServeConfig builder methods.
    let b: fn(ServeConfig, BatchPolicy) -> ServeConfig = ServeConfig::batch;
    let p: fn(ServeConfig, usize) -> ServeConfig = ServeConfig::patches;
    let d: fn(ServeConfig, Arc<dyn DispatchPolicy>) -> ServeConfig = ServeConfig::dispatch;
    let c: fn(ServeConfig, bool) -> ServeConfig = ServeConfig::co_batch;
    let r: fn(ServeConfig, RebalancePolicy) -> ServeConfig = ServeConfig::rebalance;
    let s: fn(&ServeConfig) -> String = ServeConfig::summary;
    let m: fn(&ServeConfig, ClusterSpec, SpAlgo) -> Result<SimService, ParallelSpecError> =
        ServeConfig::sim_service;
    let _ = (b, p, d, c, r, s, m);

    let parse: fn(&str) -> Option<Arc<dyn DispatchPolicy>> = dispatch_policy_from_name;
    let _ = parse;
}

/// The split traits compose back into `ServiceModel` via the blanket
/// impl — for concrete models, trait objects, and plan-agnostic models
/// that only implement `CostModel` plus an empty `Planner`.
fn is_service_model<T: ServiceModel + ?Sized>() {}
fn is_dispatch_policy<T: DispatchPolicy>() {}
fn is_fleet_model<T: FleetModel>() {}

#[test]
fn trait_composition_is_pinned() {
    is_service_model::<SimService>();
    is_service_model::<dyn ServiceModel>();
    is_dispatch_policy::<LeastLoaded>();
    is_dispatch_policy::<EarliestFinish>();
    is_fleet_model::<SimFleet>();

    struct OnlyCost;
    impl CostModel for OnlyCost {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            batch as f64
        }
    }
    impl Planner for OnlyCost {}
    is_service_model::<OnlyCost>();
}

/// Method signatures of the two trait halves, pinned through their
/// object types (this also proves both traits stay object-safe).
fn pin_cost_model(m: &dyn CostModel, w: &Workload, carve: Option<&ParallelSpec>) -> (f64, f64) {
    (m.service_time(w, 2), m.service_time_under(w, 2, carve))
}

#[allow(clippy::type_complexity)]
fn pin_planner(
    p: &dyn Planner,
    w: &Workload,
    from: &ParallelSpec,
) -> (Result<(), String>, Option<String>, Option<ParallelSpec>, Option<f64>) {
    (p.admit(w), p.plan_label(w), p.plan_spec(w), p.recarve_gain(w, from))
}

/// The subset-planning half of [`Planner`] (group-granular re-carving):
/// footprint-sized plan resolution and the split-gain prediction.
#[allow(clippy::type_complexity)]
fn pin_subset_planner(
    p: &dyn Planner,
    w: &Workload,
    from: &ParallelSpec,
    machines: usize,
) -> (Option<ParallelSpec>, Option<f64>) {
    (p.plan_spec_on(w, machines), p.partial_recarve_gain(w, from, machines))
}

#[test]
fn trait_method_signatures_are_pinned() {
    let svc = SimService::auto_plan(ClusterSpec::new(2, 2), SpAlgo::SwiftFusion);
    let w = Workload::flux_3072();
    let spec = ParallelSpec::single(&ClusterSpec::new(2, 2), w.shape.h);
    let (t, t_under) = pin_cost_model(&svc, &w, Some(&spec));
    assert!(t.is_finite() && t > 0.0);
    assert!(t_under > 0.0 || t_under.is_infinite());
    let (admit, label, plan, gain) = pin_planner(&svc, &w, &spec);
    assert!(admit.is_ok());
    assert!(label.is_some() && plan.is_some());
    let _ = gain;
    // subset planning: an auto-planning SimService sizes a carve to a
    // 1-machine subset of its 2-machine pod and predicts the split gain
    let (sub, sub_gain) = pin_subset_planner(&svc, &w, &spec, 1);
    assert!(sub.is_some_and(|s| s.total_ranks() == 2));
    assert!(sub_gain.is_some());
    // plan-agnostic models keep the do-not-plan defaults
    struct NoPlan;
    impl CostModel for NoPlan {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            batch as f64
        }
    }
    impl Planner for NoPlan {}
    let (sub, sub_gain) = pin_subset_planner(&NoPlan, &w, &spec, 1);
    assert!(sub.is_none() && sub_gain.is_none());
}

/// Public data-shape pins: constructing these structs field-by-field
/// fails to compile if a field is renamed, retyped, or removed.
#[test]
fn report_and_event_shapes_are_pinned() {
    let out = DispatchOutcome { start: 1.0, done: 2.0 };
    assert!(out.done >= out.start);

    let c = Completion { id: 7, workload: "flux-3072", arrival: 0.5, done: 2.5, pod: 0 };
    assert_eq!(c.latency(), 2.0);

    let ev = RebalanceEvent {
        at: 3.0,
        from_pod: 1,
        to_pod: 0,
        from_machines: 1,
        to_machines: 3,
    };
    assert_eq!(ev.from_machines + ev.to_machines, 4);

    let state = ServeState::default();
    let _: &Vec<(u64, f64, f64)> = &state.completions;
    let _: &Vec<(u64, String)> = &state.rejected;
    let _: &Vec<RebalanceEvent> = &state.rebalances;
    assert_eq!(state.co_batched, 0);
    assert_eq!(state.co_batched_cross, 0);

    // group-granular re-carving shapes
    let ge = GroupEpoch {
        index: 0,
        base_machine: 1,
        machines: 3,
        plan: None,
        started_at: 2.0,
        served: 4,
        merged_at: Some(9.0),
    };
    assert_eq!(ge.label(), "single-mesh");
    let pr = PartialRecarve {
        narrowed: None,
        side: None,
        base_machine: 1,
        machines: 3,
        setup: 0.05,
    };
    assert_eq!(pr.base_machine + pr.machines, 4);

    let batch = Batch {
        requests: vec![Request {
            id: 0,
            workload: Workload::flux_3072(),
            arrival: 0.0,
            seed: 0,
        }],
    };
    assert_eq!(batch.size(), 1);
}
