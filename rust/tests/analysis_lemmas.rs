//! Integration: Appendix-D closed forms vs the *measured* byte counters
//! of the executable schedules. The formulas and the running system must
//! tell the same story — this is what makes the analysis module's figures
//! trustworthy.

use swiftfusion::cluster::exec::{run_in_world, ExecMode};
use swiftfusion::comm::{Buf, CommWorld};
use swiftfusion::config::{AttnShape, ClusterSpec, SpDegrees};
use swiftfusion::sp::{SpAlgo, SpParams};

/// Run `algo` in timing mode and return mean measured inter-machine
/// bytes received per GPU.
fn measured_inter_bytes(
    n: usize,
    m: usize,
    algo: SpAlgo,
    deg: SpDegrees,
    shape: AttnShape,
) -> f64 {
    let cluster = ClusterSpec::new(n, m);
    let p = cluster.total_gpus();
    let params = SpParams { shape, chunk: shape.l / p, mesh: algo.mesh(&cluster, deg) };
    let world = CommWorld::new(cluster.clone());
    run_in_world(&world, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        algo.run(ctx, &params, s.clone(), s.clone(), s);
    });
    (0..p).map(|r| world.traffic(r).inter_in).sum::<f64>() / p as f64
}

#[test]
fn ring_measured_matches_formula() {
    // Ring over N machines x 1 GPU: formula 2·(N-1)/N·BLHD elements.
    let shape = AttnShape::new(1, 8192, 4, 32);
    for n in [2usize, 4] {
        let got = measured_inter_bytes(n, 1, SpAlgo::Ring, SpDegrees::new(1, n), shape);
        let want = swiftfusion::analysis::v_ring(&shape, n, 1) * 4.0;
        let rel = (got - want).abs() / want;
        assert!(rel < 0.05, "N={n}: measured {got} vs formula {want}");
    }
}

#[test]
fn ulysses_measured_matches_formula() {
    let shape = AttnShape::new(1, 8192, 4, 32);
    for n in [2usize, 4] {
        let got =
            measured_inter_bytes(n, 1, SpAlgo::Ulysses, SpDegrees::new(n, 1), shape);
        let want = swiftfusion::analysis::v_ulysses(&shape, n, 1) * 4.0;
        let rel = (got - want).abs() / want;
        assert!(rel < 0.05, "N={n}: measured {got} vs formula {want}");
    }
}

#[test]
fn usp_vs_tas_measured_ordering_matches_lemma() {
    // 4 machines x 2 GPUs, H = 8. USP at (Pu=2 intra), TAS at gcd = 8.
    let shape = AttnShape::new(1, 8192, 8, 32);
    let usp = measured_inter_bytes(4, 2, SpAlgo::Usp, SpDegrees::new(2, 4), shape);
    let tas = measured_inter_bytes(4, 2, SpAlgo::Tas, SpDegrees::new(8, 1), shape);
    assert!(
        tas < usp,
        "lemma D.1 in the executable system: TAS {tas} < USP {usp}"
    );
    // and the formulas predict the same ordering
    let f_usp = swiftfusion::analysis::v_usp(&shape, 4, 2, SpDegrees::new(2, 4));
    let f_tas = swiftfusion::analysis::v_sfu(&shape, 4, 2, SpDegrees::new(8, 1));
    assert!(f_tas < f_usp);
}

#[test]
fn swiftfusion_inter_volume_equals_tas() {
    // Overlap and one-sidedness change *when* bytes move, not *how many*.
    let shape = AttnShape::new(1, 8192, 8, 32);
    let tas = measured_inter_bytes(2, 2, SpAlgo::Tas, SpDegrees::new(2, 2), shape);
    let sfu =
        measured_inter_bytes(2, 2, SpAlgo::SwiftFusion, SpDegrees::new(2, 2), shape);
    let rel = (tas - sfu).abs() / tas;
    assert!(rel < 0.05, "TAS {tas} vs SFU {sfu}");
}

#[test]
fn usp_inter_volume_does_not_shrink_with_machines() {
    // Challenge 1, measured: USP's per-GPU inter volume is ~constant in N.
    let shape = AttnShape::new(1, 16384, 8, 32);
    let v2 = measured_inter_bytes(2, 2, SpAlgo::Usp, SpDegrees::new(2, 2), shape);
    let v4 = measured_inter_bytes(4, 2, SpAlgo::Usp, SpDegrees::new(2, 4), shape);
    assert!(v4 > 0.8 * v2, "USP volume must not shrink: {v2} -> {v4}");
    // while SwiftFusion's DOES shrink
    let s2 = measured_inter_bytes(2, 2, SpAlgo::SwiftFusion, SpDegrees::new(4, 1), shape);
    let s4 = measured_inter_bytes(4, 2, SpAlgo::SwiftFusion, SpDegrees::new(8, 1), shape);
    assert!(s4 < s2 * 0.8, "SFU volume must shrink: {s2} -> {s4}");
}

#[test]
fn memory_overhead_sfu_close_to_usp() {
    // Fig. 7 memory claim, measured on windows: SwiftFusion's one-sided
    // buffers must not exceed ~2x the USP communication footprint.
    let shape = AttnShape::new(1, 8192, 8, 32);
    let cluster = ClusterSpec::new(2, 2);
    let peak = |algo: SpAlgo, deg: SpDegrees| {
        let params = SpParams {
            shape,
            chunk: shape.l / 4,
            mesh: algo.mesh(&cluster, deg),
        };
        let world = CommWorld::new(cluster.clone());
        run_in_world(&world, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![shape.b, shape.l / 4, shape.h, shape.d]);
            algo.run(ctx, &params, s.clone(), s.clone(), s);
        });
        (0..4).map(|r| world.peak_window_bytes(r)).fold(0.0, f64::max)
    };
    let sfu = peak(SpAlgo::SwiftFusion, SpDegrees::new(2, 2));
    // shard bytes: one rank's Q/K/V/O = 4 tensors
    let shard = shape.bytes_per_tensor() / 4.0;
    assert!(
        sfu < 8.0 * shard,
        "one-sided windows must stay within a few shard copies: {sfu} vs shard {shard}"
    );
}
