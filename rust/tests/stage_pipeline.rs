//! Integration coverage for the decoupled multi-stage request pipeline
//! (stage DAGs, stage-class pods, inter-stage queues):
//!
//! * the stage cost decomposition partitions the monolithic price: the
//!   per-stage `time_share`s of every workload sum to exactly 1, so a
//!   staged fleet and a monolithic fleet price the same total work
//!   under the same `SimService`;
//! * with the `stages` knob off the report carries no `stages` section
//!   (the monolithic JSON goldens stay byte-identical); with it on, the
//!   section appears and accounts every stage dispatch;
//! * stage-completion event ordering is deterministic: two identical
//!   staged runs serialize to byte-equal `to_json`;
//! * a tight burst actually pipelines — request n's diffusion overlaps
//!   request n-1's decode (overlap_time > 0).

use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{ServeConfig, ServeSession};
use swiftfusion::coordinator::stages::{StagePlacement, StagePolicy};
use swiftfusion::coordinator::CostModel;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{phased_trace, Request, StageClass, Workload};

/// The serve-test convention: paper shapes shrunk to 2 layers x 2 steps
/// so the timing simulations stay fast.
fn short_workload() -> Workload {
    let mut w = Workload::short_image_4k();
    w.layers = 2;
    w.steps = 2;
    w
}

fn long_workload() -> Workload {
    let mut w = Workload::cfg_video_96k();
    w.layers = 2;
    w.steps = 2;
    w
}

fn staged_config() -> ServeConfig {
    ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .stages(StagePolicy::new(StagePlacement::balanced(3)))
}

/// A burst of `n` videos arriving every `spacing` seconds — far tighter
/// than a stage time, so consecutive requests occupy different stages
/// concurrently.
fn video_burst(n: usize, spacing: f64) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: long_workload(),
            arrival: i as f64 * spacing,
            seed: i as u64,
        })
        .collect()
}

fn run_staged(reqs: Vec<Request>) -> ServeReport {
    let mut router = Router::new(3, 8, 3, SpAlgo::SwiftFusion);
    let config = staged_config();
    let svc = config
        .sim_service(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion)
        .expect("auto planner on the 1x8 pod");
    ServeSession::new(config, &svc).run(&mut router, reqs)
}

/// The per-stage `time_share`s partition the monolithic request price
/// exactly: summed against a real `SimService`'s closed-form service
/// time they reproduce it to fp round-off, for every paper workload.
/// This is the invariant that makes the staged-vs-monolithic bench a
/// fair fight — the staged fleet is never given cheaper work.
#[test]
fn stage_costs_partition_the_sim_service_price() {
    let cluster = swiftfusion::config::ClusterSpec::paper_testbed();
    let svc = SimService::auto_plan(cluster, SpAlgo::SwiftFusion);
    let mut suite = Workload::paper_suite();
    suite.push(short_workload());
    suite.push(long_workload());
    for w in &suite {
        let shares: f64 = w.stage_shapes().iter().map(|s| s.time_share).sum();
        assert!(
            (shares - 1.0).abs() < 1e-12,
            "{}: stage shares sum to {shares}",
            w.name
        );
        let mono = svc.service_time(w, 1);
        let staged: f64 = w
            .stage_shapes()
            .iter()
            .map(|s| s.time_share * mono)
            .sum();
        assert!(
            (staged - mono).abs() <= 1e-9 * mono,
            "{}: staged serial sum {staged} vs monolithic {mono}",
            w.name
        );
        // the DiT step loop dominates; the encoder is negligible
        let sh = w.stage_shapes();
        assert!(
            sh[StageClass::Diffusion.index()].time_share
                > sh[StageClass::TextEncode.index()].time_share,
            "{}",
            w.name
        );
    }
}

/// Knob off → no `stages` key in the serialized report (the existing
/// monolithic goldens stay untouched); knob on → the section appears,
/// every request completes, and all three stage classes dispatched.
#[test]
fn stages_section_is_additive() {
    let trace = || phased_trace(&[(&short_workload(), 2), (&long_workload(), 2)]);

    let monolithic = {
        let mut router = Router::new(3, 8, 3, SpAlgo::SwiftFusion);
        let config = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 1, window: 0.0 })
            .plan(PlanPolicy::Auto);
        let svc = config
            .sim_service(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion)
            .expect("auto planner");
        ServeSession::new(config, &svc).run(&mut router, trace())
    };
    assert_eq!(monolithic.metrics.completed(), 4);
    assert!(monolithic.stages.is_none());
    assert!(
        !to_string(&monolithic.to_json()).contains("\"stages\""),
        "knob-off JSON must not gain a stages key"
    );

    let staged = run_staged(trace());
    assert_eq!(staged.metrics.completed(), 4, "every request crosses the DAG");
    assert!(staged.rejected.is_empty());
    let st = staged.stages.as_ref().expect("knob-on report carries the section");
    // one dispatch per stage per request
    assert_eq!(st.dispatches.values().sum::<usize>(), 3 * 4);
    let json = to_string(&staged.to_json());
    assert!(json.contains("\"stages\""), "{json}");
    assert!(json.contains("\"overlap_time\""), "{json}");

    // the effective-config line names the staged layout, knob-off lines
    // are unchanged
    let line = staged_config().summary();
    assert!(line.ends_with("stages=enc1/dit1/vae1 q8"), "{line}");
    assert!(!ServeConfig::new().summary().contains("stages="), "knob-off summary");
}

/// Stage-completion events drain in the deterministic `(time, seq)`
/// order: two identical staged runs — fresh routers, fresh services —
/// serialize to byte-equal reports.
#[test]
fn staged_runs_are_deterministic_byte_for_byte() {
    let a = run_staged(video_burst(6, 0.05));
    let b = run_staged(video_burst(6, 0.05));
    assert_eq!(to_string(&a.to_json()), to_string(&b.to_json()));
    assert_eq!(a.metrics.completed(), 6);
}

/// A tight burst actually pipelines: while request n denoises, request
/// n-1 decodes on the VAE pod — the overlap the staged fleet exists for.
#[test]
fn tight_burst_overlaps_diffusion_with_decode() {
    let report = run_staged(video_burst(6, 0.05));
    assert_eq!(report.metrics.completed(), 6);
    let st = report.stages.as_ref().expect("stages section");
    assert!(
        st.overlap_time > 0.0,
        "diffusion and decode never overlapped: {st:?}"
    );
    // every stage class ran under its own carve label
    for prefix in ["text-encode:", "diffusion:", "vae-decode:"] {
        assert!(
            report.plan_histogram.keys().any(|k| k.starts_with(prefix)),
            "missing {prefix} in {:?}",
            report.plan_histogram
        );
    }
}
