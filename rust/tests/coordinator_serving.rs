//! Integration: the serving engine end-to-end on the paper's workload mix
//! with the timing-mode service model — the coordinator's behavioural
//! contract (work conservation, algorithm ordering at the serving level,
//! batching effects).

use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{serve, SimService};
use swiftfusion::coordinator::{CostModel, Planner};
use swiftfusion::coordinator::router::Router;
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::{TraceGen, Workload};

fn run_trace(algo: SpAlgo, n: usize, m: usize, nreq: usize, rate: f64) -> (f64, f64) {
    let mut router = Router::new(n, m, 1, algo);
    let svc = SimService::new(router.pods[0].cluster.clone(), algo);
    let reqs = TraceGen::new(11, rate, Workload::paper_suite()).take(nreq);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 2, window: 20.0 },
        reqs,
        &svc,
    );
    let mut metrics = report.metrics;
    let mean: f64 = metrics
        .workloads()
        .iter()
        .map(|w| metrics.latency(w).unwrap().mean())
        .sum::<f64>()
        / metrics.workloads().len() as f64;
    (mean, metrics.horizon)
}

#[test]
fn all_requests_complete_under_every_algorithm() {
    for algo in [SpAlgo::Usp, SpAlgo::Tas, SpAlgo::SwiftFusion] {
        let mut router = Router::new(2, 4, 1, algo);
        let svc = SimService::new(router.pods[0].cluster.clone(), algo);
        let reqs = TraceGen::new(5, 0.02, Workload::paper_suite()).take(12);
        let report = serve(&mut router, BatchPolicy::default(), reqs, &svc);
        assert_eq!(report.metrics.completed(), 12, "{}", algo.name());
    }
}

#[test]
fn swiftfusion_serves_faster_than_usp_at_four_machines() {
    // The paper's headline at the serving level: same trace, same
    // cluster, SwiftFusion engine finishes sooner and with lower mean
    // latency than the USP engine.
    let (usp_lat, usp_hor) = run_trace(SpAlgo::Usp, 4, 8, 16, 0.02);
    let (sfu_lat, sfu_hor) = run_trace(SpAlgo::SwiftFusion, 4, 8, 16, 0.02);
    assert!(
        sfu_lat < usp_lat,
        "mean latency: SFU {sfu_lat} < USP {usp_lat}"
    );
    assert!(sfu_hor <= usp_hor * 1.02);
    // the paper's speedup band: ~1.1-2x end-to-end
    let speedup = usp_lat / sfu_lat;
    assert!(
        (1.02..3.0).contains(&speedup),
        "speedup {speedup} out of plausible band"
    );
}

#[test]
fn service_time_grows_with_sequence_length() {
    let svc = SimService::new(swiftfusion::config::ClusterSpec::new(4, 8), SpAlgo::SwiftFusion);
    let flux = svc.service_time(&Workload::flux_3072(), 1);
    let flux4k = svc.service_time(&Workload::flux_4096(), 1);
    let video = svc.service_time(&Workload::cogvideo_20s(), 1);
    assert!(flux < flux4k, "3072 < 4096");
    assert!(flux4k < video, "image < video");
}

#[test]
fn saturated_pod_queues_requests_fifo() {
    let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
    let svc = SimService::new(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    // near-simultaneous arrivals of one workload
    let reqs = TraceGen::new(3, 1000.0, vec![Workload::flux_3072()]).take(8);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 1, window: 0.0 },
        reqs,
        &svc,
    );
    // completions must be strictly increasing (single pod, FIFO)
    let mut times: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
    let sorted = {
        let mut s = times.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    };
    assert_eq!(times, sorted);
    times.dedup();
    assert_eq!(times.len(), 8, "one completion per service slot");
}

#[test]
fn batching_reduces_horizon_under_saturation() {
    let run = |max_batch| {
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let svc = SimService::new(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(3, 1000.0, vec![Workload::flux_3072()]).take(8);
        serve(
            &mut router,
            BatchPolicy { max_batch, window: 1.0 },
            reqs,
            &svc,
        )
        .metrics
        .horizon
    };
    // batch-of-2 doubles B per run but B scales sub-2x in the sim
    // (comm constant terms amortize), so horizon must drop.
    assert!(run(2) < run(1));
}

// ---- failure injection / pathological traces ------------------------------

struct FlakyService {
    /// Every k-th batch takes 10x longer (straggler injection).
    k: usize,
    counter: std::sync::atomic::AtomicUsize,
    base: f64,
}

impl CostModel for FlakyService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        let n = self
            .counter
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let straggle = if n % self.k == self.k - 1 { 10.0 } else { 1.0 };
        self.base * batch as f64 * straggle
    }
}

impl Planner for FlakyService {}

#[test]
fn stragglers_delay_but_never_drop_requests() {
    let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
    let svc = FlakyService {
        k: 3,
        counter: std::sync::atomic::AtomicUsize::new(0),
        base: 1.0,
    };
    let reqs = TraceGen::new(21, 5.0, vec![Workload::flux_3072()]).take(30);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 2, window: 0.1 },
        reqs,
        &svc,
    );
    assert_eq!(report.metrics.completed(), 30);
    for (_, arrival, done) in &report.completions {
        assert!(done > arrival);
    }
}

#[test]
fn empty_trace_is_a_clean_noop() {
    let mut router = Router::new(1, 2, 1, SpAlgo::SwiftFusion);
    let svc = SimService::new(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let report = serve(&mut router, BatchPolicy::default(), Vec::new(), &svc);
    assert_eq!(report.metrics.completed(), 0);
    assert!(report.completions.is_empty());
}

#[test]
fn burst_of_identical_arrivals_is_work_conserving() {
    // 64 requests at t=0 on 4 pods: total busy time must equal
    // 64/batch * service (no pod idles while work is queued).
    let mut router = Router::new(4, 2, 4, SpAlgo::SwiftFusion);
    struct Const;
    impl CostModel for Const {
        fn service_time(&self, _w: &Workload, _b: usize) -> f64 {
            1.0
        }
    }
    impl Planner for Const {}
    let reqs: Vec<_> = (0..64)
        .map(|i| swiftfusion::workload::Request {
            id: i,
            workload: Workload::flux_3072(),
            arrival: 0.0,
            seed: i,
        })
        .collect();
    let report = serve(
        &mut router,
        // window > 0 so simultaneous arrivals pair up into full batches
        // (window = 0 closes singletons immediately by design)
        BatchPolicy { max_batch: 2, window: 0.5 },
        reqs,
        &Const,
    );
    // 32 batches over 4 pods at 1s each -> horizon exactly 8s
    assert!((report.metrics.horizon - 8.0).abs() < 1e-9, "{}", report.metrics.horizon);
}

#[test]
fn mixed_workloads_all_complete_under_backlog() {
    let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
    let svc = SimService::new(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    // arrival rate far above service rate: deep backlog
    let reqs = TraceGen::new(33, 10.0, Workload::paper_suite()).take(40);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 4, window: 5.0 },
        reqs,
        &svc,
    );
    assert_eq!(report.metrics.completed(), 40);
    // under backlog, later arrivals must see longer latencies on average
    let first10: f64 = report.completions[..10].iter().map(|c| c.2 - c.1).sum();
    let last10: f64 = report.completions[report.completions.len() - 10..]
        .iter()
        .map(|c| c.2 - c.1)
        .sum();
    assert!(last10 > first10, "queueing delay must build up");
}
