//! Sensitivity + robustness sweeps: does the system's *qualitative*
//! story survive perturbations of the calibrated constants? These are
//! the checks a skeptical reviewer would run — if a conclusion flips
//! under a mild constant change, the reproduction would be fragile.

use swiftfusion::cluster::exec::{run_cluster, ExecMode};
use swiftfusion::comm::Buf;
use swiftfusion::config::{AttnShape, ClusterSpec, NetSpec, SpDegrees};
use swiftfusion::sp::{SpAlgo, SpParams};

fn layer_time_with(cluster: &ClusterSpec, algo: SpAlgo, shape: AttnShape) -> f64 {
    let p = cluster.total_gpus();
    let deg = match algo {
        SpAlgo::Usp => {
            let pu = swiftfusion::config::gcd(cluster.gpus_per_machine, shape.h);
            SpDegrees::new(pu, p / pu)
        }
        _ => SpDegrees::swiftfusion_default(cluster, shape.h),
    };
    let params = SpParams { shape, chunk: shape.l / p, mesh: algo.mesh(cluster, deg) };
    run_cluster(cluster, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        algo.run(ctx, &params, s.clone(), s.clone(), s);
    })
    .makespan()
}

fn paper_shape() -> AttnShape {
    AttnShape::new(1, 96 * 1024, 24, 64)
}

#[test]
fn sfu_beats_usp_across_bandwidth_band() {
    // The headline must hold for effective EFA bandwidths anywhere in
    // the plausible 12.5-40 GB/s band, not just at the calibrated 25.
    for bw in [12.5e9, 20e9, 25e9, 32e9, 40e9] {
        let mut cluster = ClusterSpec::new(4, 8);
        cluster.net.inter_bw = bw;
        let usp = layer_time_with(&cluster, SpAlgo::Usp, paper_shape());
        let sfu = layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape());
        assert!(
            sfu < usp,
            "SFU must beat USP at inter_bw={bw}: {sfu} vs {usp}"
        );
    }
}

#[test]
fn advantage_shrinks_as_networks_converge() {
    // Paper premise inverted: if inter-machine bandwidth approached
    // NVSwitch, topology-awareness must stop mattering.
    let speedup_at = |bw: f64| {
        let mut cluster = ClusterSpec::new(4, 8);
        cluster.net.inter_bw = bw;
        layer_time_with(&cluster, SpAlgo::Usp, paper_shape())
            / layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape())
    };
    let slow = speedup_at(12.5e9);
    let fast = speedup_at(300e9);
    assert!(slow > fast, "gap must narrow: {slow} -> {fast}");
    assert!(fast < 1.35, "near parity networks leave little to win: {fast}");
}

#[test]
fn commodity_preset_widens_the_gap() {
    let mut commodity = ClusterSpec::new(4, 8);
    commodity.net = NetSpec::commodity_100g();
    let efa = ClusterSpec::new(4, 8);
    let gap = |c: &ClusterSpec| {
        layer_time_with(c, SpAlgo::Usp, paper_shape())
            / layer_time_with(c, SpAlgo::SwiftFusion, paper_shape())
    };
    assert!(gap(&commodity) > gap(&efa));
}

#[test]
fn commodity_carries_host_side_constants() {
    // NetSpec::commodity_100g documents its unchanged constants as
    // deliberate p4de carry-overs (GPU-side SM/stream costs, host-side
    // rendezvous/barrier paths — none of them fabric terms). Pin the
    // carry-over so a future edit to either preset re-opens the
    // question, then show the comparison this preset feeds (the
    // USP-vs-SwiftFusion gap on the commodity fabric) is insensitive to
    // plausible perturbations of each carried constant: the conclusion
    // rests on the intra/inter bandwidth gap, not on the inherited
    // host-side numbers.
    let p4de = NetSpec::p4de_efa();
    let comm = NetSpec::commodity_100g();
    assert_eq!(comm.sm_tax, p4de.sm_tax);
    assert_eq!(comm.two_sided_sync, p4de.two_sided_sync);
    assert_eq!(comm.barrier_lat, p4de.barrier_lat);
    assert_eq!(comm.two_sided_stream_block, p4de.two_sided_stream_block);
    assert_eq!(comm.intra_bw, p4de.intra_bw);
    assert_eq!(comm.intra_lat, p4de.intra_lat);
    assert!(comm.inter_bw < p4de.inter_bw, "only the link terms change");
    assert!(comm.inter_lat > p4de.inter_lat);

    let gap_with = |tweak: &dyn Fn(&mut NetSpec)| {
        let mut cluster = ClusterSpec::new(4, 8);
        cluster.net = NetSpec::commodity_100g();
        tweak(&mut cluster.net);
        layer_time_with(&cluster, SpAlgo::Usp, paper_shape())
            / layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape())
    };
    let baseline = gap_with(&|_| {});
    assert!(baseline > 1.0, "SFU must win on commodity: {baseline}");
    let perturbations: [(&str, &dyn Fn(&mut NetSpec)); 6] = [
        ("sm_tax 0", &|n| n.sm_tax = 0.0),
        ("sm_tax x2", &|n| n.sm_tax *= 2.0),
        ("two_sided_sync /2", &|n| n.two_sided_sync /= 2.0),
        ("two_sided_sync x2", &|n| n.two_sided_sync *= 2.0),
        ("barrier_lat /2", &|n| n.barrier_lat /= 2.0),
        ("barrier_lat x2", &|n| n.barrier_lat *= 2.0),
    ];
    for (name, tweak) in perturbations {
        let gap = gap_with(tweak);
        assert!(
            gap > 1.0,
            "conclusion flipped under {name}: gap {gap} (baseline {baseline})"
        );
        assert!(
            (gap / baseline - 1.0).abs() < 0.25,
            "{name} moved the gap more than 25%: {gap} vs {baseline} — \
             the carried constant is not a second-order term after all"
        );
    }
}

#[test]
fn stream_block_zero_still_leaves_one_sided_ahead() {
    // Even with perfectly async two-sided transfers (stream_block = 0,
    // generous to NCCL), SwiftFusion must not lose: it still avoids the
    // rendezvous penalty and the SM bandwidth tax.
    let mut cluster = ClusterSpec::new(4, 8);
    cluster.net.two_sided_stream_block = 0.0;
    let tas = layer_time_with(&cluster, SpAlgo::Tas, paper_shape());
    let sfu = layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape());
    assert!(sfu <= tas * 1.02, "SFU {sfu} vs TAS {tas}");
}

#[test]
fn sm_tax_zero_preserves_volume_ordering() {
    let mut cluster = ClusterSpec::new(4, 8);
    cluster.net.sm_tax = 0.0;
    let usp = layer_time_with(&cluster, SpAlgo::Usp, paper_shape());
    let sfu = layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape());
    assert!(sfu < usp, "volume advantage alone must suffice at 4x8");
}

#[test]
fn compute_bound_regime_compresses_all_gaps() {
    // 10x faster network OR 10x slower GPU -> everything compute-bound;
    // algorithms converge. Checks the model doesn't produce magical
    // speedups where none should exist.
    let mut cluster = ClusterSpec::new(4, 8);
    cluster.gpu.flops /= 10.0;
    let usp = layer_time_with(&cluster, SpAlgo::Usp, paper_shape());
    let sfu = layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape());
    let ratio = usp / sfu;
    assert!(
        (0.95..1.25).contains(&ratio),
        "compute-bound regime should compress the gap: {ratio}"
    );
}

#[test]
fn single_gpu_degenerates_to_pure_compute() {
    let cluster = ClusterSpec::new(1, 1);
    let shape = AttnShape::new(1, 4096, 8, 64);
    for algo in [SpAlgo::Ring, SpAlgo::Ulysses, SpAlgo::SwiftFusion] {
        let params = SpParams {
            shape,
            chunk: shape.l,
            mesh: algo.mesh(&cluster, SpDegrees::new(1, 1)),
        };
        let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![1, shape.l, shape.h, shape.d]);
            let out = algo.run(ctx, &params, s.clone(), s.clone(), s);
            assert_eq!(out.shape(), &[1, shape.l, shape.h, shape.d]);
        });
        let (_, comm, sync, _) = run.mean_breakdown();
        assert!(
            comm + sync < run.makespan() * 0.05,
            "{}: single GPU must be ~pure compute",
            algo.name()
        );
    }
}

#[test]
fn makespan_monotone_in_sequence_length() {
    let cluster = ClusterSpec::new(2, 4);
    let mut prev = 0.0;
    for lk in [32usize, 64, 128] {
        let shape = AttnShape::new(1, lk * 1024, 8, 64);
        let t = layer_time_with(&cluster, SpAlgo::SwiftFusion, shape);
        assert!(t > prev, "L={lk}k: {t} must exceed {prev}");
        prev = t;
    }
}

#[test]
fn determinism_of_the_timing_engine() {
    // Repeated threaded simulations must produce IDENTICAL virtual
    // times (the determinism claim of comm/mod.rs).
    let cluster = ClusterSpec::new(2, 4);
    let t: Vec<f64> = (0..3)
        .map(|_| layer_time_with(&cluster, SpAlgo::SwiftFusion, paper_shape()))
        .collect();
    assert_eq!(t[0], t[1]);
    assert_eq!(t[1], t[2]);
}
